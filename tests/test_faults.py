"""Overload and failure semantics: preemption, load shedding, launch
retry, and the deterministic fault-injection harness.

The chaos oracles (docs/serving.md "Overload and failure semantics"):

  * **No deadlock** — every faulted run drains within a bounded number
    of steps.
  * **Bit-identity** — per-(request, tier) token streams are
    deterministic functions of (prompt, tier params) under greedy
    decode, so surviving requests must produce streams identical to a
    fault-free run: preemption replays, retry relaunches, pool
    shrinkage, and escalation storms (which change *routing*, never a
    tier's tokens) all leave them untouched.
  * **Conservation** — submitted == completed + shed + failed at drain.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serving import (BlockAllocator, CascadeEngine, FaultPlan,
                           Request, RequestState, SlotAllocator, TierSpec,
                           TransientError)
from repro.serving.engine import VirtualClock
from repro.serving.faults import Shrink, Storm
from repro.serving.request import TERMINAL_STATES
from repro.serving.scheduler import CascadeScheduler, GateSpec
from repro.serving.slots import TierSlotPool


# ---------------------------------------------------------------------------
# FaultPlan: parsing and determinism
# ---------------------------------------------------------------------------


def test_fault_plan_parse_full_grammar():
    p = FaultPlan.parse("seed=7,shrink=5:0:8:40,storm=10-14:1,"
                        "launch=0.05:2,launchat=3:1:4,slow=0.1:0.01")
    assert p.seed == 7
    assert p.shrinks == (Shrink(5, 0, 8, 40),)
    assert p.storms == (Storm(10, 14, 1),)
    assert p.launch_fail_prob == 0.05 and p.launch_fail_attempts == 2
    assert p.fail_launches == {(3, 1): 4}
    assert p.slow_tick_prob == 0.1 and p.slow_tick_seconds == 0.01
    # defaults: restore never, gate 0, one failing attempt
    p2 = FaultPlan.parse("shrink=1:0:4,storm=2-3,launchat=5:0")
    assert p2.shrinks[0].restore_tick is None
    assert p2.storms[0].gate == 0
    assert p2.fail_launches == {(5, 0): 1}


@pytest.mark.parametrize("bad", [
    "frobnicate=1", "shrink=1:2", "storm=5", "slow=0.5", "launch",
])
def test_fault_plan_parse_rejects(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_fault_plan_draws_are_pure_and_order_independent():
    a, b = FaultPlan(seed=3), FaultPlan(seed=3)
    keys = [(1, 0, 1), (9, 1, 5), (1, 0, 1), (2, 0, 3)]
    # same key -> same draw regardless of what was drawn before
    assert [a._draw(*k) for k in keys] \
        == [b._draw(*k) for k in reversed(keys)][::-1]
    assert a._draw(1, 0, 1) == a._draw(1, 0, 1)
    assert FaultPlan(seed=4)._draw(1, 0, 1) != a._draw(1, 0, 1)


def test_fault_plan_pre_launch_targets_and_recovers():
    p = FaultPlan(fail_launches={(2, 0): 2})
    with pytest.raises(TransientError):
        p.pre_launch(2, 0, "run_mixed", 0)
    with pytest.raises(TransientError):
        p.pre_launch(2, 0, "run_mixed", 1)
    p.pre_launch(2, 0, "run_mixed", 2)      # attempts exhausted: passes
    p.pre_launch(3, 0, "run_mixed", 0)      # other ticks untouched
    assert [e[1] for e in p.log] == ["launch_fault", "launch_fault"]


def test_fault_plan_storm_window():
    p = FaultPlan(storms=(Storm(5, 8, gate=1),))
    assert p.force_escalation(4, 1) is None
    assert p.force_escalation(5, 1) is True
    assert p.force_escalation(7, 1) is True
    assert p.force_escalation(8, 1) is None         # end-exclusive
    assert p.force_escalation(6, 0) is None         # other gate


# ---------------------------------------------------------------------------
# satellite: double-free / double-release guards
# ---------------------------------------------------------------------------


def test_slot_allocator_double_free_raises():
    a = SlotAllocator(2)
    s = a.alloc()
    a.free(s)
    with pytest.raises(ValueError, match="double free"):
        a.free(s)
    with pytest.raises(ValueError, match="double free"):
        a.free(1 - s)                       # never allocated


def test_block_allocator_double_free_raises():
    a = BlockAllocator(4)
    b = a.alloc()
    a.free(b)
    with pytest.raises(ValueError, match="double free"):
        a.free(b)
    with pytest.raises(ValueError, match="double free"):
        a.free(0)                           # the null block


def _pool():
    from repro.configs import get_config
    cfg = get_config("gemma3-1b", "smoke")
    return TierSlotPool(cfg, capacity=4, max_seq=16, block_size=4,
                        num_blocks=13)


def test_tier_slot_pool_double_release_raises():
    pool = _pool()
    pool.bind(0, 8)
    pool.release(0)
    with pytest.raises(ValueError, match="double release"):
        pool.release(0)
    with pytest.raises(ValueError, match="double release"):
        pool.release(1)                     # never bound


# ---------------------------------------------------------------------------
# fault-injected pool shrinkage: deadlock-safety caps
# ---------------------------------------------------------------------------


def test_shrink_caps_preserve_floor_and_oldest_reserve():
    pool = _pool()                          # 12 usable blocks, 4/row
    pool.bind(0, 4, row_tokens=16)          # oldest: holds 1, demands 3 more
    # floor cap: usable - pages_per_row = 12 - 4 = 8; reserve cap:
    # free (11) - oldest_worst (3) = 8 -> a huge request takes only 8
    assert pool.shrink(100) == 8
    assert pool.blocks.reserved_in(0) == 8
    # the oldest row can still grow to its full demand
    assert pool.ensure_blocks(0, 15)
    assert pool.unshrink() == 8
    assert pool.shrink(2) == 2              # partial shrink under the cap
    pool.unshrink()


def test_shrink_keeps_one_full_request_admissible():
    pool = _pool()
    pool.shrink(100)                        # empty pool: floor cap binds
    assert pool.blocks.num_free >= pool.pages_per_row
    assert pool.can_admit(16)
    pool.unshrink()


# ---------------------------------------------------------------------------
# request lifecycle: new states
# ---------------------------------------------------------------------------


def test_request_overload_transitions():
    r = Request(rid=0, prompt=np.zeros(4, np.int32), gen_len=2,
                arrival_time=0.0)
    r.admit(0, 0, 1.0)
    r.preempt(2.0)
    assert r.state is RequestState.PREEMPTED and r.preemptions == 1
    assert r.slot is None
    r.admit(0, 1, 3.0)                      # replay resets partial work
    assert r.tokens == [] and r.token_conf == []
    r.start_decode(4.0)
    r.fail(5.0)
    assert r.state in TERMINAL_STATES
    with pytest.raises(ValueError):
        r.admit(0, 0, 6.0)                  # terminal states stay terminal

    q = Request(rid=1, prompt=np.zeros(4, np.int32), gen_len=2,
                arrival_time=0.0, deadline=1.0)
    q.shed(2.0)
    assert q.state is RequestState.SHED
    with pytest.raises(ValueError):
        q.shed(3.0)


# ---------------------------------------------------------------------------
# scheduler: shedding pass and preempted re-queue
# ---------------------------------------------------------------------------


def _sched_req(rid, arrival=0.0, deadline=None):
    return Request(rid=rid, prompt=np.zeros(4, np.int32), gen_len=2,
                   arrival_time=arrival, deadline=deadline)


def test_scheduler_shed_expired_and_unmeetable():
    sched = CascadeScheduler([2, 2], [GateSpec(delta=0.5)])
    keep = _sched_req(0, deadline=None)           # no deadline: never shed
    expired = _sched_req(1, deadline=5.0)
    tight = _sched_req(2, deadline=12.0)          # meetable without floor
    for r in (keep, expired, tight):
        sched.submit(r)
    shed = sched.shed(0, now=10.0, floor=None)
    assert [r.rid for r in shed] == [1]
    assert [r.rid for r in sched.queues[0]] == [0, 2]   # order preserved
    # with a service-time floor, provably-unmeetable deadlines shed too
    shed = sched.shed(0, now=10.0, floor=lambda r: 5.0)
    assert [r.rid for r in shed] == [2]


def test_scheduler_requeue_puts_preempted_at_head():
    sched = CascadeScheduler([2, 2], [GateSpec(delta=0.5)])
    a, b = _sched_req(0), _sched_req(1)
    sched.submit(a)
    sched.submit(b)
    victim = _sched_req(2)
    sched.requeue(victim, 0)
    assert [r.rid for r in sched.queues[0]] == [2, 0, 1]


# ---------------------------------------------------------------------------
# engine chaos suite (smoke models)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_parts():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    p0 = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    p1 = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    return cfg, p0, p1


def _build(parts, tiers=1, **kw):
    cfg, p0, p1 = parts
    specs = [TierSpec("fast", cfg, p0)]
    if tiers == 2:
        specs.append(TierSpec("exp", cfg, p1))
        kw.setdefault("deltas", [0.5])
    kw.setdefault("retry_backoff", 0.0)
    kw.setdefault("slots", 2)
    kw.setdefault("prompt_len", 16)
    kw.setdefault("gen_len", 4)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("kv_block_size", 4)
    return CascadeEngine(specs, clock=VirtualClock(), **kw)


def _prompts(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
            for _ in range(n)]


def _drain(eng, prompts, deadline=None, max_steps=500):
    for p in prompts:
        eng.submit(p, arrival_time=0.0, deadline=deadline)
    s = eng.run(max_steps=max_steps)
    assert all(r.state in TERMINAL_STATES for r in eng.requests)
    assert s["conservation"]["ok"], s["conservation"]
    return s


def _streams(eng):
    return {r.rid: list(r.tokens) for r in eng.requests}


@pytest.fixture(scope="module")
def ref_streams(tiny_parts):
    """Fault-free single-tier reference streams (the chaos oracle)."""
    eng = _build(tiny_parts)
    _drain(eng, _prompts(tiny_parts[0]))
    return _streams(eng)


@pytest.mark.parametrize("policy", ["youngest", "fewest-tokens"])
def test_preemption_replays_bit_identical(tiny_parts, ref_streams, policy):
    # 4 slots into a 14-block arena (pages_per_row=5): over-subscribed,
    # rows stall mid-decode -> the policy evicts and replays instead
    eng = _build(tiny_parts, slots=4, kv_blocks=14,
                 preemption_policy=policy)
    s = _drain(eng, _prompts(tiny_parts[0]))
    assert s["preemptions"] > 0 and s["replayed_tokens"] > 0
    assert s["completed"] == 6 and s["failed"] == 0
    assert _streams(eng) == ref_streams
    assert all(r.preemptions == 0 or r.state is RequestState.DONE
               for r in eng.requests)


def test_preemption_requires_chunked_paged_path(tiny_parts):
    with pytest.raises(ValueError, match="preemption"):
        _build(tiny_parts, use_paged_kv=False,
               preemption_policy="youngest")
    with pytest.raises(ValueError, match="preemption_policy"):
        _build(tiny_parts, preemption_policy="oldest")


def test_deadline_shedding_conserves(tiny_parts):
    # 2 slots, 6 requests, deadlines only the first waves can meet
    eng = _build(tiny_parts)
    s = _drain(eng, _prompts(tiny_parts[0]), deadline=6.0)
    assert s["shed"] > 0 and s["completed"] > 0
    assert s["shed"] + s["completed"] == s["submitted"] == 6
    assert 0.0 < s["shed_rate"] < 1.0
    shed = [r for r in eng.requests if r.state is RequestState.SHED]
    assert all(r.deadline is not None for r in shed)
    # no-deadline submissions are never shed even under the same load
    eng = _build(tiny_parts)
    s = _drain(eng, _prompts(tiny_parts[0]))
    assert s["shed"] == 0 and s["completed"] == 6


def test_transient_launch_failures_recover_bit_identical(
        tiny_parts, ref_streams):
    # 2 consecutive failures < the default 2-retry budget: invisible
    # beyond the retry counter
    eng = _build(tiny_parts, faults=FaultPlan(fail_launches={(2, 0): 2}))
    s = _drain(eng, _prompts(tiny_parts[0]))
    assert s["launch_retries"] > 0 and s["failed"] == 0
    assert s["completed"] == 6
    assert _streams(eng) == ref_streams


def test_retry_exhaustion_fails_one_not_the_run(tiny_parts, ref_streams):
    # every launch at tick 2 fails persistently: each exhausted launch
    # sacrifices one victim; the engine and the other requests survive
    eng = _build(tiny_parts, faults=FaultPlan(fail_launches={(2, 0): 99}))
    s = _drain(eng, _prompts(tiny_parts[0]))
    assert s["failed"] >= 1
    assert s["failed"] + s["completed"] == 6
    survivors = {r.rid: list(r.tokens) for r in eng.requests
                 if r.state is RequestState.DONE}
    assert survivors and all(ref_streams[rid] == t
                             for rid, t in survivors.items())


def test_escalation_storm_forces_routing_not_tokens(tiny_parts,
                                                    ref_streams):
    # δ=0 never escalates; the storm forces every gate decision up.
    # Tier-0 streams are still bit-identical to the fault-free run
    # (storms change routing, not a tier's deterministic decode).
    eng = _build(tiny_parts, tiers=2, deltas=[0.0],
                 faults=FaultPlan(storms=(Storm(1, 1000, 0),)))
    s = _drain(eng, _prompts(tiny_parts[0]))
    assert all(r.tier == 1 for r in eng.requests)
    assert all(list(r.tokens_by_tier[0]) == ref_streams[r.rid]
               for r in eng.requests)
    assert s["completed"] == 6
    # gate stats saw the forced decisions like real traffic
    assert s["escalation_rates"][0] == 1.0


def test_combo_chaos_no_deadlock_and_survivor_identity(tiny_parts,
                                                       ref_streams):
    # shrink + storm + probabilistic transient launch failures at once,
    # two tiers, over-subscribed arena with preemption
    plan = FaultPlan(seed=11,
                     shrinks=(Shrink(tick=3, tier=0, blocks=6,
                                     restore_tick=9),),
                     storms=(Storm(4, 7, 0),),
                     launch_fail_prob=0.2)
    eng = _build(tiny_parts, tiers=2, slots=4, kv_blocks=[14, None],
                 preemption_policy="youngest", faults=plan)
    s = _drain(eng, _prompts(tiny_parts[0]))       # asserts conservation
    assert s["completed"] + s["failed"] == 6
    # retries absorbed every probabilistic fault (attempts=1 < budget)
    assert s["failed"] == 0 and s["launch_retries"] > 0
    # tier-0 streams of every request match the fault-free oracle
    assert all(list(r.tokens_by_tier[0]) == ref_streams[r.rid]
               for r in eng.requests)
    assert len(plan.log) > 0                        # faults actually fired


def test_fault_determinism_same_seed_same_run(tiny_parts):
    def chaos():
        plan = FaultPlan(seed=5, launch_fail_prob=0.3,
                         shrinks=(Shrink(tick=2, tier=0, blocks=4,
                                         restore_tick=6),))
        eng = _build(tiny_parts, slots=4, kv_blocks=14,
                     preemption_policy="fewest-tokens", faults=plan)
        s = _drain(eng, _prompts(tiny_parts[0]))
        return _streams(eng), plan.log, s["preemptions"], \
            s["launch_retries"]
    assert chaos() == chaos()


def test_drain_failure_reports_diagnostics(tiny_parts):
    eng = _build(tiny_parts)
    for p in _prompts(tiny_parts[0], n=3):
        eng.submit(p)
    with pytest.raises(RuntimeError) as exc:
        eng.run(max_steps=1)
    msg = str(exc.value)
    assert "did not drain" in msg
    assert "queued=" in msg and "live_rows=" in msg
    assert "stalled_rows=" in msg and "free_blocks_by_shard=" in msg


# ---------------------------------------------------------------------------
# serve_async CLI: overload flags and KeyboardInterrupt handling
# ---------------------------------------------------------------------------


class _InterruptingClock(VirtualClock):
    """Raises KeyboardInterrupt after `ticks` engine steps."""

    def __init__(self, ticks):
        super().__init__()
        self._left = ticks

    def step_done(self):
        super().step_done()
        self._left -= 1
        if self._left <= 0:
            raise KeyboardInterrupt


def _cli_args(tmp_path, *extra):
    from repro.launch import serve_async
    return serve_async.make_parser().parse_args([
        "--requests", "8", "--rate", "4", "--slots", "2",
        "--prompt-len", "16", "--gen-len", "4", "--prefill-chunk", "8",
        "--kv-block-size", "4", "--expensive", "gemma3-1b",
        "--virtual-clock", "--retry-backoff", "0", *extra])


def test_serve_async_overload_flags(tmp_path, capsys):
    from repro.launch import serve_async
    args = _cli_args(tmp_path, "--kv-blocks", "14",
                     "--preemption", "youngest", "--deadline", "64",
                     "--inject-faults", "launchat=3:0:1")
    s = serve_async.run(args, clock=VirtualClock())
    assert s["conservation"]["ok"] and not s["interrupted"]
    assert s["preemption_policy"] == "youngest"
    assert s["faults"]["fail_launches"] == {"3:0": 1}
    assert s["launch_retries"] >= 1
    serve_async.report(s)
    assert "overload [youngest]" in capsys.readouterr().out


def test_serve_async_keyboard_interrupt_partial_summary(tmp_path):
    from repro.launch import serve_async
    trace = tmp_path / "trace.json"
    args = _cli_args(tmp_path, "--trace-out", str(trace))
    s = serve_async.run(args, clock=_InterruptingClock(4))
    assert s["interrupted"]
    assert s["completed"] < 8                  # stopped mid-run
    assert trace.exists() and s["trace_events"] > 0


# ---------------------------------------------------------------------------
# chaos x prefix cache: shrink and preemption storms against a warm index
# ---------------------------------------------------------------------------


def _shared_prompts(cfg, n=8, seed=0):
    """Prompts agreeing on their first 9 tokens (warm prefix-cache
    traffic) with unique 3-token tails."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    out = []
    for _ in range(n):
        p = base.copy()
        p[9:] = rng.integers(0, cfg.vocab_size, 3)
        out.append(p)
    return out


@pytest.fixture(scope="module")
def shared_ref_streams(tiny_parts):
    """Fault-free, cache-off reference streams for the shared-prefix
    workload (the chaos x prefix-cache oracle)."""
    eng = _build(tiny_parts, slots=4)
    _drain(eng, _shared_prompts(tiny_parts[0]))
    return _streams(eng)


def _checked_shrink(pool):
    """Wrap `pool.shrink` to audit, at every shrink, that withheld
    blocks are never referenced (shrink draws from the free list only —
    a refcount > 0 block must never be pulled out from under a reader)
    and that the full allocator invariant suite still holds."""
    from tests.test_slots_properties import check_invariants
    orig = pool.shrink

    def shrink(n):
        took = orig(n)
        withheld = {b for lst in pool.blocks._reserved for b in lst}
        live = set(pool.blocks._refcount)
        assert not (withheld & live), \
            f"shrink withheld referenced blocks {withheld & live}"
        check_invariants(pool)
        return took

    pool.shrink = shrink
    return pool


def test_shrink_against_warm_prefix_cache(tiny_parts, shared_ref_streams):
    """Mid-run pool shrinkage while the prefix index is warm: withheld
    blocks must all be unreferenced (free-list only), streams stay
    bit-identical, and conservation holds at drain."""
    from tests.test_slots_properties import check_invariants
    plan = FaultPlan(seed=3, shrinks=(Shrink(tick=3, tier=0, blocks=6,
                                             restore_tick=10),))
    eng = _build(tiny_parts, slots=4, kv_blocks=14, prefix_cache=True,
                 preemption_policy="youngest", faults=plan)
    _checked_shrink(eng.runtimes[0].pool)
    s = _drain(eng, _shared_prompts(tiny_parts[0]))
    assert s["completed"] == 8 and s["failed"] == 0
    assert _streams(eng) == shared_ref_streams
    assert any(e[1] == "shrink" for e in plan.log)     # shrink fired
    check_invariants(eng.runtimes[0].pool)


def test_preemption_storm_against_warm_prefix_cache(tiny_parts,
                                                    shared_ref_streams):
    """Preemption churn on an over-subscribed arena with the cache on:
    releasing a victim whose blocks the index still references reclaims
    nothing out from under a reader, replays may legitimately re-hit the
    cache, and every stream matches the fault-free cache-off oracle."""
    from tests.test_slots_properties import check_invariants
    eng = _build(tiny_parts, slots=4, kv_blocks=16, prefix_cache=True,
                 preemption_policy="youngest")
    s = _drain(eng, _shared_prompts(tiny_parts[0]))
    assert s["completed"] == 8 and s["failed"] == 0
    assert _streams(eng) == shared_ref_streams
    assert s["prefix_cache"]["hits"] > 0               # the cache was warm
    assert s["preemptions"] > 0                        # churn really hit it
    check_invariants(eng.runtimes[0].pool)


def test_combo_chaos_with_prefix_cache(tiny_parts, shared_ref_streams):
    """The full storm: shrink + escalation storm + probabilistic launch
    failures, two tiers, over-subscribed tier-0 arena, preemption, and
    the prefix cache on in both tiers.  Tier-0 streams of every request
    still match the fault-free cache-off oracle and both pools'
    invariants hold at drain."""
    from tests.test_slots_properties import check_invariants
    plan = FaultPlan(seed=11,
                     shrinks=(Shrink(tick=3, tier=0, blocks=6,
                                     restore_tick=9),),
                     storms=(Storm(4, 7, 0),),
                     launch_fail_prob=0.2)
    eng = _build(tiny_parts, tiers=2, slots=4, kv_blocks=[14, None],
                 prefix_cache=True, preemption_policy="youngest",
                 faults=plan)
    _checked_shrink(eng.runtimes[0].pool)
    s = _drain(eng, _shared_prompts(tiny_parts[0]))
    assert s["completed"] + s["failed"] == 8
    assert all(list(r.tokens_by_tier[0]) == shared_ref_streams[r.rid]
               for r in eng.requests)
    assert len(plan.log) > 0
    for rt in eng.runtimes:
        check_invariants(rt.pool)


# ---------------------------------------------------------------------------
# chaos x speculative cascade decoding: shrink + preemption churn while
# the expensive tier verifies drafted tokens on provisional KV
# ---------------------------------------------------------------------------


def test_speculation_chaos_matches_k0_oracle(tiny_parts):
    """Speculative decoding under pool shrinkage and preemption churn on
    BOTH over-subscribed arenas: draft rows are retained cheap-tier rows
    and rejected verify suffixes are provisional KV writes, so the chaos
    suite's two guarantees must survive them — the slots invariant
    checker stays green on every pool, and streams (and terminal states)
    are bit-identical to the k=0 escalation-only oracle.  δ=1.0
    escalates every request, so the verify path sees all six; greedy
    acceptance emits scoring-tier argmaxes only, which is why parity
    holds at k>0, not just k=0."""
    from tests.test_slots_properties import check_invariants

    def chaos(k):
        plan = FaultPlan(seed=7,
                         shrinks=(Shrink(tick=3, tier=0, blocks=5,
                                         restore_tick=9),
                                  Shrink(tick=5, tier=1, blocks=5,
                                         restore_tick=11)))
        eng = _build(tiny_parts, tiers=2, slots=4, kv_blocks=[14, 14],
                     deltas=[1.0], preemption_policy="youngest",
                     faults=plan, speculation_k=k,
                     spec_delta=0.0 if k else None)
        _checked_shrink(eng.runtimes[0].pool)
        _checked_shrink(eng.runtimes[1].pool)
        s = _drain(eng, _prompts(tiny_parts[0]))
        for rt in eng.runtimes:
            check_invariants(rt.pool)
            # no draft row leaks a binding past drain
            assert all(r is None for r in rt.draft_req)
        assert any(e[1] == "shrink" for e in plan.log)
        return eng, s

    oracle_eng, oracle = chaos(0)
    assert oracle["completed"] == 6
    for k in (2, 4):
        eng, s = chaos(k)
        assert s["completed"] == 6 and s["failed"] == 0
        assert _streams(eng) == _streams(oracle_eng)
        assert {r.rid: r.state for r in eng.requests} \
            == {r.rid: r.state for r in oracle_eng.requests}
        sp = s["speculation"]
        assert sp["drafted"] > 0
        assert sp["drafted"] == sp["accepted"] + sp["rolled_back"]
