"""Speculative cascade decoding: draft cheap, batch-verify expensive.

Acceptance tests for ``speculation_k``:

* **Bit-identical streams.**  Emitted tokens are always scoring-model
  argmaxes (accepted draft prefix + the verifier's bonus token), so
  every ``k`` — including the ``k=0`` escalation-only oracle — must
  produce byte-for-byte the same token streams and confidences as a
  plain engine with no speculation at all.
* **One launch + one device_get per active tier per tick.**  The
  verify forward, accept/reject epilogue, and the draft scan are fused
  into a single compiled program per tier, and the tick's results come
  back through one blocking fetch per tier — speculation must not
  regress the unified-step contract.
* **The speedup mechanism engages.**  Under self-speculation (both
  tiers share parameters) every draft is accepted, so a k-draft tick
  emits k+1 tokens per verify row and the run finishes in fewer ticks
  with fewer expensive-tier launches.
* **Accept/reject telemetry** feeds the draft tier's gate calibration
  as a bias-free ground-truth stream.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import CascadeEngine, TierSpec, VirtualClock
from repro.serving.request import RequestState


@pytest.fixture(scope="module")
def tiny_parts():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    fast_p = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    exp_p = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    return cfg, fast_p, exp_p


def _mk(cfg, fast_p, exp_p, k, **kw):
    kw.setdefault("slots", 4)
    kw.setdefault("prompt_len", 24)
    kw.setdefault("gen_len", 8)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("kv_block_size", 4)
    kw.setdefault("deltas", [1.0])        # escalate everything
    kw.setdefault("clock", VirtualClock())
    if k:
        kw.setdefault("speculation_k", k)
        kw.setdefault("spec_delta", 0.0)  # stage every drafted token
    return CascadeEngine([TierSpec("fast", cfg, fast_p),
                          TierSpec("exp", cfg, exp_p)], **kw)


def _prompts(cfg, n=6, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, rng.integers(4, 24))
            .astype(np.int32) for _ in range(n)]


def _drain(eng, prompts):
    for p in prompts:
        eng.submit(p, arrival_time=0.0)
    s = eng.run(max_steps=800)
    assert all(r.state is RequestState.DONE for r in eng.requests)
    assert s["conservation"]["ok"], s["conservation"]
    return s


def _streams(eng):
    return {r.rid: (list(r.tokens),
                    [round(float(c), 6) for c in r.token_conf])
            for r in eng.requests}


def test_spec_streams_match_escalation_only_oracle(tiny_parts):
    """Acceptance: with distinct fast/expensive models the emitted
    streams at k∈{2,4} are bit-identical to the k=0 oracle AND to a
    plain engine with speculation disabled entirely — greedy
    speculative decoding never changes what the verifier would have
    said token by token."""
    cfg, fast_p, exp_p = tiny_parts
    prompts = _prompts(cfg)
    plain = _mk(cfg, fast_p, exp_p, 0)
    _drain(plain, prompts)
    oracle = _mk(cfg, fast_p, exp_p, 0, speculation_k=0)
    _drain(oracle, prompts)
    assert _streams(oracle) == _streams(plain)
    for k in (2, 4):
        eng = _mk(cfg, fast_p, exp_p, k)
        s = _drain(eng, prompts)
        assert _streams(eng) == _streams(plain), f"k={k} diverged"
        assert s["speculation"]["drafted"] > 0, \
            f"k={k} never staged a draft"


def test_spec_tick_pays_one_launch_and_one_sync(tiny_parts):
    """Acceptance: in speculation mode each tick still executes at
    most ONE compiled program and ONE blocking device fetch per active
    tier — the fused verify+accept+draft launch, tick by tick and in
    aggregate, with no mid-run recompiles."""
    cfg, fast_p, _ = tiny_parts
    eng = _mk(cfg, fast_p, fast_p, 4)
    eng.warmup()
    for p in _prompts(cfg, n=5):
        eng.submit(p, arrival_time=0.0)
    for _ in range(400):
        before_l = list(eng.metrics.launches_by_tier)
        before_s = list(eng.metrics.host_syncs_by_tier)
        eng.step()
        for t in range(2):
            dl = eng.metrics.launches_by_tier[t] - before_l[t]
            ds = eng.metrics.host_syncs_by_tier[t] - before_s[t]
            assert dl <= 1, f"tier {t} paid {dl} launches in one tick"
            assert ds <= 1, f"tier {t} paid {ds} fetches in one tick"
        if all(r.state is RequestState.DONE for r in eng.requests):
            break
    assert all(r.state is RequestState.DONE for r in eng.requests)
    s = eng.metrics.summary()
    assert max(s["launches_per_tick"]) <= 1.0 + 1e-9
    assert max(s["host_syncs_per_tick"]) <= 1.0 + 1e-9
    for rep in eng.compile_stats():
        assert rep["mid_run_recompiles"] == [], rep


def test_self_speculation_multiplies_tokens_per_tick(tiny_parts):
    """With tied parameters the verifier agrees with every draft
    (accept rate 1), so k>0 finishes the same workload in strictly
    fewer ticks and fewer expensive-tier launches than k=0 — while
    emitting identical streams."""
    cfg, fast_p, _ = tiny_parts
    prompts = _prompts(cfg, n=6, seed=9)
    runs = {}
    for k in (0, 4):
        eng = _mk(cfg, fast_p, fast_p, k, speculation_k=k,
                  spec_delta=0.0 if k else None, gen_len=12)
        runs[k] = (eng, _drain(eng, prompts))
    (e0, s0), (e4, s4) = runs[0], runs[4]
    assert _streams(e4) == _streams(e0)
    assert s4["steps"] < s0["steps"], (s4["steps"], s0["steps"])
    assert s4["launches"][1] < s0["launches"][1]
    sp = s4["speculation"]
    assert sp["drafted"] > 0
    assert sp["accepted"] == sp["drafted"]        # tied params: all accept
    assert sp["accept_rate"] == pytest.approx(1.0)
    assert sp["drafted"] == sp["accepted"] + sp["rolled_back"]


def test_verify_outcomes_feed_gate_calibration(tiny_parts):
    """Satellite: accept/reject verdicts stream into the draft tier's
    GateCalibration as ground-truth samples (conf vs verifier
    agreement), separate from the escalation-censored stream."""
    cfg, fast_p, exp_p = tiny_parts
    eng = _mk(cfg, fast_p, exp_p, 3)
    _drain(eng, _prompts(cfg))
    cal = eng.metrics.calibration
    assert cal.verify_outcomes[0] > 0
    rate = cal.verify_accept_rate(0)
    assert 0.0 <= rate <= 1.0
    g = cal.summary()[0]
    assert g["verify_outcomes"] == cal.verify_outcomes[0]
    assert g["verify_accept_rate"] == pytest.approx(rate)
    # self-speculation: the ground-truth stream reads accept rate 1
    eng2 = _mk(cfg, fast_p, fast_p, 3)
    _drain(eng2, _prompts(cfg, n=4))
    assert eng2.metrics.calibration.verify_accept_rate(0) \
        == pytest.approx(1.0)


def test_speculation_config_validation(tiny_parts):
    cfg, fast_p, exp_p = tiny_parts
    with pytest.raises(ValueError, match=">= 0"):
        _mk(cfg, fast_p, exp_p, 0, speculation_k=-1)
    with pytest.raises(ValueError, match="two"):
        CascadeEngine([TierSpec("t", cfg, fast_p)], slots=2,
                      prompt_len=16, gen_len=4, deltas=[],
                      speculation_k=2)
    with pytest.raises(ValueError, match="ragged"):
        _mk(cfg, fast_p, exp_p, 0, speculation_k=2,
            use_ragged_step=False)
    with pytest.raises(ValueError, match="spec_delta"):
        _mk(cfg, fast_p, exp_p, 0, spec_delta=0.5)
