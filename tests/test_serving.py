"""Async cascade serving runtime: slots, scheduler, gating, engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.server import (CascadeServer, ServingMember,
                               delta_for_escalation_rate)
from repro.serving import (CascadeScheduler, GateSpec, Request, RequestState,
                           SlotAllocator)
from repro.serving.request import sequence_confidence


# ---------------------------------------------------------------------------
# slot allocator
# ---------------------------------------------------------------------------


def test_slot_allocator_exhaustion_and_reuse():
    a = SlotAllocator(3)
    got = [a.alloc() for _ in range(3)]
    assert sorted(got) == [0, 1, 2]
    assert a.alloc() is None            # exhausted
    assert a.num_free == 0 and a.num_used == 3 and a.utilization == 1.0
    a.free(got[1])
    assert a.num_free == 1
    again = a.alloc()
    assert again == got[1]              # free-list reuse
    with pytest.raises(ValueError):
        a.free(99)                      # double/stray free is an error


# ---------------------------------------------------------------------------
# scheduler: continuous batching + escalation queues
# ---------------------------------------------------------------------------


def _req(rid, arrival=0.0, gen_len=2):
    return Request(rid=rid, prompt=np.zeros(4, np.int32), gen_len=gen_len,
                   arrival_time=arrival)


def test_scheduler_admits_mid_decode():
    """The continuous-batching invariant: a freed slot is refilled from the
    queue on the next admission pass, without waiting for the rest of the
    batch to drain."""
    sched = CascadeScheduler([2, 1], [GateSpec(delta=0.5)])
    reqs = [_req(i) for i in range(4)]
    for r in reqs:
        sched.submit(r)

    admitted, slots = sched.admit(0, now=0.0)
    assert [r.rid for r in admitted] == [0, 1] and len(slots) == 2
    sched.check_invariant(0.0)          # both slots busy, queue waits

    # request 0 finishes mid-decode of request 1 -> slot frees -> request 2
    # is admitted immediately
    admitted[0].start_decode()
    admitted[0].emit(7, 0.9, 1.0)
    admitted[0].emit(7, 0.9, 2.0)
    conf = admitted[0].gate()
    assert not sched.gate_decision(0, conf)     # 0.9 > δ: stays
    admitted[0].complete(2.0)
    sched.release(0, slots[0])
    more, more_slots = sched.admit(0, now=2.0)
    assert [r.rid for r in more] == [2] and more_slots == [slots[0]]
    sched.check_invariant(2.0)
    assert sched.pending == 1           # request 3 still queued


def test_scheduler_respects_arrival_times():
    sched = CascadeScheduler([4], [])
    sched.submit(_req(0, arrival=5.0))
    assert sched.admit(0, now=1.0) == ([], [])   # not arrived yet
    got, _ = sched.admit(0, now=5.0)
    assert [r.rid for r in got] == [0]


def test_escalation_queue_feeds_next_tier_packed():
    sched = CascadeScheduler([4, 2], [GateSpec(delta=0.5)])
    reqs = [_req(i, gen_len=1) for i in range(4)]
    for r in reqs:
        sched.submit(r)
    admitted, _ = sched.admit(0, now=0.0)
    for r in admitted:
        slot = r.slot
        r.start_decode()
        r.emit(1, 0.1 if r.rid % 2 == 0 else 0.9, 0.0)
        conf = r.gate()
        if sched.gate_decision(0, conf):
            r.escalate()
            sched.push_escalated(r)
        else:
            r.complete(0.0)
        sched.release(0, slot)
    # rids 0 and 2 (conf 0.1 <= δ) escalated; tier 1 admits them packed
    packed, slots = sched.admit(1, now=1.0)
    assert [r.rid for r in packed] == [0, 2]
    assert slots == [0, 1]
    assert sched.gate_stats[0].seen == 4
    assert sched.gate_stats[0].escalated == 2


def test_request_illegal_transitions_raise():
    r = _req(0)
    with pytest.raises(ValueError):
        r.complete(0.0)                 # QUEUED -> DONE is illegal
    r.admit(0, 0, 0.0)
    with pytest.raises(ValueError):
        r.emit(1, 0.5, 0.0)             # must start_decode first


# ---------------------------------------------------------------------------
# δ from escalation budget
# ---------------------------------------------------------------------------


def test_delta_for_escalation_rate_edge_cases():
    assert delta_for_escalation_rate([], 0.5) == 0.5       # empty confs
    confs = np.linspace(0.01, 0.99, 99)
    d0 = delta_for_escalation_rate(confs, 0.0)
    assert (confs <= d0).mean() <= 0.02                    # ~nothing
    d1 = delta_for_escalation_rate(confs, 1.0)
    assert (confs <= d1).mean() == 1.0                     # everything
    assert d1 == pytest.approx(confs.max())


def test_budget_gate_converges_to_target():
    sched = CascadeScheduler([1, 1], [GateSpec(budget=0.2, window=256,
                                               min_calibration=4)])
    rng = np.random.default_rng(0)
    esc = 0
    n = 400
    for _ in range(n):
        esc += bool(sched.gate_decision(0, float(rng.random())))
    assert abs(esc / n - 0.2) < 0.08


def test_gate_spec_validation():
    with pytest.raises(ValueError):
        GateSpec()                      # neither delta nor budget
    with pytest.raises(ValueError):
        GateSpec(delta=0.5, budget=0.2)  # both


def test_sequence_confidence_reductions():
    c = [0.5, 0.8, 0.9]
    assert sequence_confidence(c, "mean") == pytest.approx(np.mean(c))
    assert sequence_confidence(c, "min") == pytest.approx(0.5)
    assert sequence_confidence(c, "prod") == pytest.approx(0.5 * 0.8 * 0.9)
    assert sequence_confidence([], "mean") == 0.0


# ---------------------------------------------------------------------------
# engine integration (smoke models)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_engine_parts():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    fast_p = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    exp_p = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    return cfg, fast_p, exp_p


def _make_engine(cfg, fast_p, exp_p, **kw):
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock
    kw.setdefault("slots", 2)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("gen_len", 4)
    kw.setdefault("clock", VirtualClock())
    return CascadeEngine([TierSpec("fast", cfg, fast_p),
                          TierSpec("exp", cfg, exp_p)], **kw)


def test_engine_continuous_batching_drains_and_holds_invariant(
        tiny_engine_parts):
    cfg, fast_p, exp_p = tiny_engine_parts
    eng = _make_engine(cfg, fast_p, exp_p, deltas=[0.5])
    rng = np.random.default_rng(0)
    for i in range(6):                   # 6 requests into 2 slots/tier
        eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                   arrival_time=float(i % 3))
    while not eng._done():
        eng.step(eng.clock.now())
        eng.scheduler.check_invariant(eng.clock.now())
        eng.clock.step_done()
    assert all(r.state is RequestState.DONE for r in eng.requests)
    assert all(len(r.tokens) == 4 for r in eng.requests)
    assert all(r.latency is not None and r.latency >= 0
               for r in eng.requests)
    s = eng.metrics.summary()
    assert s["completed"] == 6
    # Eq 7: realized cost within the always-fast / always-expensive envelope
    assert s["flops_per_request_always_fast"] \
        <= s["flops_per_request_cascade"] \
        <= s["flops_per_request_always_expensive"]


def test_clock_reset():
    import time as _time
    from repro.serving.engine import VirtualClock, WallClock
    w = WallClock()
    _time.sleep(0.01)
    before = w.now()
    w.reset()
    assert w.now() < before
    v = VirtualClock()
    v.step_done()
    v.step_done()
    assert v.now() == 2.0
    v.reset()
    assert v.now() == 0.0


def test_warmup_resets_clock(tiny_engine_parts):
    """Compile time must not count against request latency: warmup ends
    by resetting the clock, so arrival timestamps submitted afterwards
    are relative to the start of serving."""
    cfg, fast_p, exp_p = tiny_engine_parts
    eng = _make_engine(cfg, fast_p, exp_p, deltas=[0.5])
    for _ in range(3):
        eng.clock.step_done()           # time passes before serving
    assert eng.clock.now() == 3.0
    eng.warmup()
    assert eng.clock.now() == 0.0


def test_engine_out_of_order_arrivals_do_not_hang(tiny_engine_parts):
    """Admission is FIFO, so a queue head with a late arrival blocks
    earlier-submitted-later times; the idle jump must target the head's
    arrival (jumping to min() spins a VirtualClock forever)."""
    cfg, fast_p, exp_p = tiny_engine_parts
    eng = _make_engine(cfg, fast_p, exp_p, deltas=[0.5])
    rng = np.random.default_rng(4)
    eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
               arrival_time=10.0)
    eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
               arrival_time=1.0)
    s = eng.run(max_steps=500)
    assert s["completed"] == 2
    assert all(r.state is RequestState.DONE for r in eng.requests)


def test_engine_escalation_matches_cascade_server(tiny_engine_parts):
    """The async engine's gate must agree with the synchronous
    CascadeServer on identical confidence traffic."""
    cfg, fast_p, exp_p = tiny_engine_parts
    delta = 0.5
    eng = _make_engine(cfg, fast_p, exp_p, deltas=[delta])
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (5, 8)).astype(np.int32)
    for p in prompts:
        eng.submit(p, arrival_time=0.0)
    eng.run()

    confs = np.asarray([r.seq_conf_by_tier[0] for r in eng.requests])
    members = [
        ServingMember("fast", lambda pr: (np.zeros((pr.shape[0], 1)),
                                          confs[:pr.shape[0]]), 1.0),
        ServingMember("exp", lambda pr: (np.ones((pr.shape[0], 1)),
                                         np.ones(pr.shape[0])), 10.0),
    ]
    srv = CascadeServer(members, deltas=[delta])
    srv.serve(prompts)
    assert srv.stats.gates[0].escalated \
        == eng.scheduler.gate_stats[0].escalated
    assert eng.scheduler.gate_stats[0].escalated == int((confs <= delta).sum())
    # escalated requests were re-decoded by the expensive tier
    for r in eng.requests:
        assert r.tier == (1 if r.seq_conf_by_tier[0] <= delta else 0)


def test_engine_matches_greedy_decode_reference(tiny_engine_parts):
    """The engine's fast-tier decode must reproduce the legacy synchronous
    loop (`launch.serve.greedy_decode`, kept as the independent reference
    implementation) token-for-token."""
    from repro.core import confidence as conf_lib
    from repro.launch.serve import greedy_decode

    cfg, fast_p, exp_p = tiny_engine_parts
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)

    eng = _make_engine(cfg, fast_p, exp_p, deltas=[-1.0], slots=3)
    for p in prompts:
        eng.submit(p, arrival_time=0.0)     # δ=-1: nothing escalates
    eng.run()

    ref_tokens, ref_conf = greedy_decode(cfg, fast_p, jnp.asarray(prompts), 4)
    ref_seq = conf_lib.sequence_confidence(ref_conf, reduce="mean")
    got = np.stack([r.tokens for r in eng.requests])
    np.testing.assert_array_equal(got, np.asarray(ref_tokens))
    np.testing.assert_allclose(
        [r.seq_conf_by_tier[0] for r in eng.requests],
        np.asarray(ref_seq), rtol=1e-5)


def test_engine_staggered_positions_match_sync_decode(tiny_engine_parts):
    """Continuous batching admits mid-decode, so slots sit at different
    positions; outputs must still equal an all-at-once run (per-row decode
    positions in attention)."""
    cfg, fast_p, exp_p = tiny_engine_parts
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, (4, 8)).astype(np.int32)

    eng_sync = _make_engine(cfg, fast_p, exp_p, deltas=[0.0], slots=4)
    for p in prompts:
        eng_sync.submit(p, arrival_time=0.0)
    eng_sync.run()

    eng_stag = _make_engine(cfg, fast_p, exp_p, deltas=[0.0], slots=2)
    for i, p in enumerate(prompts):
        eng_stag.submit(p, arrival_time=float(i))   # staggered arrivals
    eng_stag.run()

    for a, b in zip(eng_sync.requests, eng_stag.requests):
        assert a.tokens == b.tokens
        np.testing.assert_allclose(a.token_conf, b.token_conf, rtol=1e-5)
