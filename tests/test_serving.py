"""Async cascade serving runtime: slots, scheduler, gating, engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.server import (CascadeServer, ServingMember,
                               delta_for_escalation_rate)
from repro.serving import (BlockAllocator, CascadeScheduler, GateSpec,
                           Request, RequestState, SlotAllocator, TierSlotPool)
from repro.serving.request import sequence_confidence


# ---------------------------------------------------------------------------
# slot allocator
# ---------------------------------------------------------------------------


def test_slot_allocator_exhaustion_and_reuse():
    a = SlotAllocator(3)
    got = [a.alloc() for _ in range(3)]
    assert sorted(got) == [0, 1, 2]
    assert a.alloc() is None            # exhausted
    assert a.num_free == 0 and a.num_used == 3 and a.utilization == 1.0
    a.free(got[1])
    assert a.num_free == 1
    again = a.alloc()
    assert again == got[1]              # free-list reuse
    with pytest.raises(ValueError):
        a.free(99)                      # double/stray free is an error


def test_block_allocator_reserves_null_block():
    a = BlockAllocator(4)                   # blocks 1..3 usable, 0 = null
    got = sorted(a.alloc() for _ in range(3))
    assert got == [1, 2, 3]                 # never hands out block 0
    assert a.alloc() is None
    a.free(2)
    assert a.alloc() == 2
    assert a.high_water == 3
    with pytest.raises(ValueError):
        a.free(0)


# ---------------------------------------------------------------------------
# block-paged slot pool
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pool_cfg():
    from repro.configs import get_config
    return get_config("gemma3-1b", "smoke")


def _rand_part_cache(cfg, capacity, prompt_len, seed):
    """A random packed-prefill cache (stand-in for transformer prefill)."""
    from repro.models import cache as cache_lib
    decl = cache_lib.declare_cache(cfg, capacity, prompt_len, jnp.float32)
    rng = np.random.default_rng(seed)
    return jax.tree.map(
        lambda c: jnp.asarray(rng.standard_normal(c.shape), c.dtype)
        if c.dtype != jnp.int8
        else jnp.asarray(rng.integers(-127, 127, c.shape), jnp.int8),
        decl, is_leaf=lambda x: isinstance(x, cache_lib.CP))


def _first_kv_pool(cache):
    """First attention layer's (k, v) block pools, stack dim stripped."""
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    leaves = {jax.tree_util.keystr(path): leaf for path, leaf in flat}
    for name, k in sorted(leaves.items()):
        if name.endswith("['k']") and k.ndim >= 4:
            v = leaves[name[:-len("['k']")] + "['v']"]
            while k.ndim > 4:               # scanned period: [stack, N,...]
                k, v = k[0], v[0]
            return k, v
    raise AssertionError("no attention KV leaf in cache")


def _paged_attn_out(pool, slot, pos, seed=0):
    """Attend over `slot`'s pages of the pool's first attention layer."""
    from repro.kernels import ref
    k, v = _first_kv_pool(pool.cache)
    KV, hd = k.shape[2], k.shape[3]
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, KV, 2, hd))
    pt = jnp.asarray(pool.page_table[slot:slot + 1])
    return ref.paged_attention_ref(q, k, v, pt,
                                   jnp.asarray([pos], jnp.int32))


def test_tier_slot_pool_freed_block_stale_keys_never_attended(pool_cfg):
    """Free a slot, rebind its blocks to a new request: the new request's
    attention must be identical to a fresh pool that never saw the old
    occupant — stale keys in reused blocks are unreachable."""
    cfg = pool_cfg
    capacity, max_seq, bs, prompt = 2, 12, 4, 8
    pool = TierSlotPool(cfg, capacity, max_seq, block_size=bs)
    old = _rand_part_cache(cfg, capacity, prompt, seed=1)
    new = _rand_part_cache(cfg, capacity, prompt, seed=2)

    pool.bind(0, prompt)
    first_blocks = list(pool._row_blocks[0])
    pool.write_prefill([0], old)            # old occupant fills its blocks
    pool.release(0)
    assert np.all(pool.page_table[0] == 0)  # pages unmapped on free

    pool.bind(0, prompt)                    # free-list reuse: same blocks
    assert set(pool._row_blocks[0]) == set(first_blocks)
    pool.write_prefill([0], new)
    got = _paged_attn_out(pool, 0, prompt - 1)

    fresh = TierSlotPool(cfg, capacity, max_seq, block_size=bs)
    fresh.bind(0, prompt)
    fresh.write_prefill([0], new)
    want = _paged_attn_out(fresh, 0, prompt - 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tier_slot_pool_partial_admission_with_recurrent_state():
    """Prefill-scatter with fewer admitted requests than capacity must
    slice recurrent ('row') leaves to the admitted count — regression:
    the paged pool only prefix-sliced the paged KV leaves, crashing
    mamba/rwkv hybrids on any partially-filled admission batch."""
    from repro.configs import get_config
    cfg = get_config("jamba-v0.1-52b", "smoke")     # mamba (recurrent) arch
    pool = TierSlotPool(cfg, capacity=3, max_seq=12, block_size=4)
    part = _rand_part_cache(cfg, 3, 8, seed=4)
    pool.bind(1, 8)
    pool.write_prefill([1], part)                   # 1 of 3 rows admitted

    def leaf(tree, key):
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        return next(v for p, v in flat
                    if jax.tree_util.keystr(p).endswith(f"['{key}']"))
    # packed row 0 of the part cache landed in request row 1 (stacked
    # period leaves: batch axis 1)
    np.testing.assert_array_equal(np.asarray(leaf(pool.cache, "ssm")[:, 1]),
                                  np.asarray(leaf(part, "ssm")[:, 0]))
    np.testing.assert_array_equal(np.asarray(leaf(pool.cache, "conv")[:, 1]),
                                  np.asarray(leaf(part, "conv")[:, 0]))


def test_tier_slot_pool_oversubscription_accounting(pool_cfg):
    """4 rows x 3 pages would need 12 blocks; a 7-usable-block pool admits
    three requests (2 prompt pages each), denies the fourth, stalls a
    younger row when the free list drains, and recovers once the oldest
    releases."""
    cfg = pool_cfg
    pool = TierSlotPool(cfg, 4, max_seq=12, block_size=4, num_blocks=8)
    assert pool.oversubscribed
    pool.bind(0, 8)                         # 2 blocks each, 5 free
    pool.bind(1, 8)                         # 3 free
    assert pool.can_admit(8)                # 3 - 2 >= worst(oldest)=1
    pool.bind(2, 8)                         # 1 free
    assert not pool.can_admit(8)            # 1 - 2 < 1: denied
    # growth: the oldest row may always take a block; younger rows must
    # leave the oldest's worst-case remaining demand free
    assert pool.ensure_blocks(0, 8)         # oldest takes the last block
    assert not pool.ensure_blocks(1, 8)     # free list empty: stall
    pool.release(0)                         # oldest finishes, frees 3
    assert pool.ensure_blocks(1, 8)         # row 1 is oldest now: retry ok
    assert pool.can_admit(8)


# ---------------------------------------------------------------------------
# scheduler: continuous batching + escalation queues
# ---------------------------------------------------------------------------


def _req(rid, arrival=0.0, gen_len=2):
    return Request(rid=rid, prompt=np.zeros(4, np.int32), gen_len=gen_len,
                   arrival_time=arrival)


def test_scheduler_admits_mid_decode():
    """The continuous-batching invariant: a freed slot is refilled from the
    queue on the next admission pass, without waiting for the rest of the
    batch to drain."""
    sched = CascadeScheduler([2, 1], [GateSpec(delta=0.5)])
    reqs = [_req(i) for i in range(4)]
    for r in reqs:
        sched.submit(r)

    admitted, slots = sched.admit(0, now=0.0)
    assert [r.rid for r in admitted] == [0, 1] and len(slots) == 2
    sched.check_invariant(0.0)          # both slots busy, queue waits

    # request 0 finishes mid-decode of request 1 -> slot frees -> request 2
    # is admitted immediately
    admitted[0].start_decode()
    admitted[0].emit(7, 0.9, 1.0)
    admitted[0].emit(7, 0.9, 2.0)
    conf = admitted[0].gate()
    assert not sched.gate_decision(0, conf)     # 0.9 > δ: stays
    admitted[0].complete(2.0)
    sched.release(0, slots[0])
    more, more_slots = sched.admit(0, now=2.0)
    assert [r.rid for r in more] == [2] and more_slots == [slots[0]]
    sched.check_invariant(2.0)
    assert sched.pending == 1           # request 3 still queued


def test_scheduler_respects_arrival_times():
    sched = CascadeScheduler([4], [])
    sched.submit(_req(0, arrival=5.0))
    assert sched.admit(0, now=1.0) == ([], [])   # not arrived yet
    got, _ = sched.admit(0, now=5.0)
    assert [r.rid for r in got] == [0]


def test_escalation_queue_feeds_next_tier_packed():
    sched = CascadeScheduler([4, 2], [GateSpec(delta=0.5)])
    reqs = [_req(i, gen_len=1) for i in range(4)]
    for r in reqs:
        sched.submit(r)
    admitted, _ = sched.admit(0, now=0.0)
    for r in admitted:
        slot = r.slot
        r.start_decode()
        r.emit(1, 0.1 if r.rid % 2 == 0 else 0.9, 0.0)
        conf = r.gate()
        if sched.gate_decision(0, conf):
            r.escalate()
            sched.push_escalated(r)
        else:
            r.complete(0.0)
        sched.release(0, slot)
    # rids 0 and 2 (conf 0.1 <= δ) escalated; tier 1 admits them packed
    packed, slots = sched.admit(1, now=1.0)
    assert [r.rid for r in packed] == [0, 2]
    assert slots == [0, 1]
    assert sched.gate_stats[0].seen == 4
    assert sched.gate_stats[0].escalated == 2


def test_request_illegal_transitions_raise():
    r = _req(0)
    with pytest.raises(ValueError):
        r.complete(0.0)                 # QUEUED -> DONE is illegal
    r.admit(0, 0, 0.0)
    with pytest.raises(ValueError):
        r.emit(1, 0.5, 0.0)             # must start_decode first


# ---------------------------------------------------------------------------
# δ from escalation budget
# ---------------------------------------------------------------------------


def test_delta_for_escalation_rate_edge_cases():
    assert delta_for_escalation_rate([], 0.5) == 0.5       # empty confs
    confs = np.linspace(0.01, 0.99, 99)
    d0 = delta_for_escalation_rate(confs, 0.0)
    assert (confs <= d0).mean() <= 0.02                    # ~nothing
    d1 = delta_for_escalation_rate(confs, 1.0)
    assert (confs <= d1).mean() == 1.0                     # everything
    assert d1 == pytest.approx(confs.max())


def test_budget_gate_converges_to_target():
    sched = CascadeScheduler([1, 1], [GateSpec(budget=0.2, window=256,
                                               min_calibration=4)])
    rng = np.random.default_rng(0)
    esc = 0
    n = 400
    for _ in range(n):
        esc += bool(sched.gate_decision(0, float(rng.random())))
    assert abs(esc / n - 0.2) < 0.08


def test_gate_spec_validation():
    with pytest.raises(ValueError):
        GateSpec()                      # neither delta nor budget
    with pytest.raises(ValueError):
        GateSpec(delta=0.5, budget=0.2)  # both


def test_sequence_confidence_reductions():
    c = [0.5, 0.8, 0.9]
    assert sequence_confidence(c, "mean") == pytest.approx(np.mean(c))
    assert sequence_confidence(c, "min") == pytest.approx(0.5)
    assert sequence_confidence(c, "prod") == pytest.approx(0.5 * 0.8 * 0.9)
    assert sequence_confidence([], "mean") == 0.0


# ---------------------------------------------------------------------------
# engine integration (smoke models)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_engine_parts():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    fast_p = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    exp_p = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    return cfg, fast_p, exp_p


def _make_engine(cfg, fast_p, exp_p, **kw):
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock
    kw.setdefault("slots", 2)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("gen_len", 4)
    kw.setdefault("clock", VirtualClock())
    return CascadeEngine([TierSpec("fast", cfg, fast_p),
                          TierSpec("exp", cfg, exp_p)], **kw)


def test_engine_continuous_batching_drains_and_holds_invariant(
        tiny_engine_parts):
    cfg, fast_p, exp_p = tiny_engine_parts
    eng = _make_engine(cfg, fast_p, exp_p, deltas=[0.5])
    rng = np.random.default_rng(0)
    for i in range(6):                   # 6 requests into 2 slots/tier
        eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                   arrival_time=float(i % 3))
    while not eng._done():
        eng.step(eng.clock.now())
        eng.scheduler.check_invariant(eng.clock.now())
        eng.clock.step_done()
    assert all(r.state is RequestState.DONE for r in eng.requests)
    assert all(len(r.tokens) == 4 for r in eng.requests)
    assert all(r.latency is not None and r.latency >= 0
               for r in eng.requests)
    s = eng.metrics.summary()
    assert s["completed"] == 6
    # Eq 7: realized cost within the always-fast / always-expensive envelope
    assert s["flops_per_request_always_fast"] \
        <= s["flops_per_request_cascade"] \
        <= s["flops_per_request_always_expensive"]


def test_clock_reset():
    import time as _time
    from repro.serving.engine import VirtualClock, WallClock
    w = WallClock()
    _time.sleep(0.01)
    before = w.now()
    w.reset()
    assert w.now() < before
    v = VirtualClock()
    v.step_done()
    v.step_done()
    assert v.now() == 2.0
    v.reset()
    assert v.now() == 0.0


def test_warmup_resets_clock(tiny_engine_parts):
    """Compile time must not count against request latency: warmup ends
    by resetting the clock, so arrival timestamps submitted afterwards
    are relative to the start of serving."""
    cfg, fast_p, exp_p = tiny_engine_parts
    eng = _make_engine(cfg, fast_p, exp_p, deltas=[0.5])
    for _ in range(3):
        eng.clock.step_done()           # time passes before serving
    assert eng.clock.now() == 3.0
    eng.warmup()
    assert eng.clock.now() == 0.0


def test_engine_out_of_order_arrivals_do_not_hang(tiny_engine_parts):
    """Admission is FIFO, so a queue head with a late arrival blocks
    earlier-submitted-later times; the idle jump must target the head's
    arrival (jumping to min() spins a VirtualClock forever)."""
    cfg, fast_p, exp_p = tiny_engine_parts
    eng = _make_engine(cfg, fast_p, exp_p, deltas=[0.5])
    rng = np.random.default_rng(4)
    eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
               arrival_time=10.0)
    eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
               arrival_time=1.0)
    s = eng.run(max_steps=500)
    assert s["completed"] == 2
    assert all(r.state is RequestState.DONE for r in eng.requests)


def test_engine_escalation_matches_cascade_server(tiny_engine_parts):
    """The async engine's gate must agree with the synchronous
    CascadeServer on identical confidence traffic."""
    cfg, fast_p, exp_p = tiny_engine_parts
    delta = 0.5
    eng = _make_engine(cfg, fast_p, exp_p, deltas=[delta])
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (5, 8)).astype(np.int32)
    for p in prompts:
        eng.submit(p, arrival_time=0.0)
    eng.run()

    confs = np.asarray([r.seq_conf_by_tier[0] for r in eng.requests])
    members = [
        ServingMember("fast", lambda pr: (np.zeros((pr.shape[0], 1)),
                                          confs[:pr.shape[0]]), 1.0),
        ServingMember("exp", lambda pr: (np.ones((pr.shape[0], 1)),
                                         np.ones(pr.shape[0])), 10.0),
    ]
    srv = CascadeServer(members, deltas=[delta])
    srv.serve(prompts)
    assert srv.stats.gates[0].escalated \
        == eng.scheduler.gate_stats[0].escalated
    assert eng.scheduler.gate_stats[0].escalated == int((confs <= delta).sum())
    # escalated requests were re-decoded by the expensive tier
    for r in eng.requests:
        assert r.tier == (1 if r.seq_conf_by_tier[0] <= delta else 0)


def test_engine_matches_greedy_decode_reference(tiny_engine_parts):
    """The engine's fast-tier decode must reproduce the legacy synchronous
    loop (`launch.serve.greedy_decode`, kept as the independent reference
    implementation) token-for-token."""
    from repro.core import confidence as conf_lib
    from repro.launch.serve import greedy_decode

    cfg, fast_p, exp_p = tiny_engine_parts
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)

    eng = _make_engine(cfg, fast_p, exp_p, deltas=[-1.0], slots=3)
    for p in prompts:
        eng.submit(p, arrival_time=0.0)     # δ=-1: nothing escalates
    eng.run()

    ref_tokens, ref_conf = greedy_decode(cfg, fast_p, jnp.asarray(prompts), 4)
    ref_seq = conf_lib.sequence_confidence(ref_conf, reduce="mean")
    got = np.stack([r.tokens for r in eng.requests])
    np.testing.assert_array_equal(got, np.asarray(ref_tokens))
    np.testing.assert_allclose(
        [r.seq_conf_by_tier[0] for r in eng.requests],
        np.asarray(ref_seq), rtol=1e-5)


def test_engine_paged_matches_dense_arena(tiny_engine_parts):
    """The block-paged decode path (default) must produce bit-identical
    token streams to the PR 1 dense one-page-per-request arena."""
    cfg, fast_p, exp_p = tiny_engine_parts
    rng = np.random.default_rng(7)
    prompts = rng.integers(0, cfg.vocab_size, (5, 8)).astype(np.int32)

    outs = []
    for paged in (True, False):
        eng = _make_engine(cfg, fast_p, exp_p, deltas=[0.5],
                           use_paged_kv=paged, kv_block_size=4)
        for i, p in enumerate(prompts):
            eng.submit(p, arrival_time=float(i % 2))
        eng.run()
        outs.append(eng.requests)
    for a, b in zip(*outs):
        assert a.tokens == b.tokens
        assert a.tier == b.tier
        np.testing.assert_allclose(a.token_conf, b.token_conf, rtol=1e-5)


def test_engine_oversubscribed_arena_admits_beyond_dense_equivalent(
        tiny_engine_parts):
    """Acceptance: with the arena sized in KV blocks, the engine holds
    more concurrent requests than a dense one-page-per-request arena of
    equal memory could, and still completes with identical tokens."""
    cfg, fast_p, exp_p = tiny_engine_parts
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock

    prompt_len, gen_len, bs = 8, 8, 4          # max_seq 16 = 4 blocks
    kv_blocks = 13                             # 12 usable = 48 tokens
    dense_equiv_requests = (kv_blocks - 1) * bs // (prompt_len + gen_len)
    assert dense_equiv_requests == 3

    def build(**kw):
        return CascadeEngine(
            [TierSpec("fast", cfg, fast_p), TierSpec("exp", cfg, exp_p)],
            slots=6, prompt_len=prompt_len, gen_len=gen_len, deltas=[0.5],
            clock=VirtualClock(), kv_block_size=bs, **kw)

    rng = np.random.default_rng(9)
    prompts = rng.integers(0, cfg.vocab_size, (8, prompt_len)).astype(np.int32)

    eng = build(kv_blocks=[kv_blocks, None])   # over-subscribed fast tier
    for p in prompts:
        eng.submit(p, arrival_time=0.0)
    peak = 0
    steps = 0
    while not eng._done():
        eng.step(eng.clock.now())
        peak = max(peak, len(eng.runtimes[0].occupied()))
        eng.clock.step_done()
        steps += 1
        assert steps < 500
    assert peak > dense_equiv_requests         # the paging win
    assert all(r.state is RequestState.DONE for r in eng.requests)
    stats = eng.memory_stats()[0]
    assert stats["kv_high_water_blocks"] <= kv_blocks - 1

    ref = build(kv_blocks=None)                # fully provisioned
    for p in prompts:
        ref.submit(p, arrival_time=0.0)
    ref.run()
    for a, b in zip(eng.requests, ref.requests):
        assert a.tokens == b.tokens            # stalls only delay, never
        np.testing.assert_allclose(            # change, the computation
            a.token_conf, b.token_conf, rtol=1e-5)


def test_engine_oversubscription_rejected_for_recurrent_state():
    """Models with mamba/rwkv state cannot replay a stalled decode step;
    the engine must refuse an over-subscribed arena for them."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import CascadeEngine, TierSpec
    cfg = get_config("jamba-v0.1-52b", "smoke")     # attn + mamba hybrid
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    with pytest.raises(ValueError, match="recurrent"):
        CascadeEngine([TierSpec("t", cfg, params)], slots=4,
                      prompt_len=8, gen_len=8, deltas=[],
                      kv_block_size=4, kv_blocks=9)
    # fully provisioned paging is fine for recurrent models
    CascadeEngine([TierSpec("t", cfg, params)], slots=2,
                  prompt_len=8, gen_len=4, deltas=[], kv_block_size=4)


def test_engine_staggered_positions_match_sync_decode(tiny_engine_parts):
    """Continuous batching admits mid-decode, so slots sit at different
    positions; outputs must still equal an all-at-once run (per-row decode
    positions in attention)."""
    cfg, fast_p, exp_p = tiny_engine_parts
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, (4, 8)).astype(np.int32)

    eng_sync = _make_engine(cfg, fast_p, exp_p, deltas=[0.0], slots=4)
    for p in prompts:
        eng_sync.submit(p, arrival_time=0.0)
    eng_sync.run()

    eng_stag = _make_engine(cfg, fast_p, exp_p, deltas=[0.0], slots=2)
    for i, p in enumerate(prompts):
        eng_stag.submit(p, arrival_time=float(i))   # staggered arrivals
    eng_stag.run()

    for a, b in zip(eng_sync.requests, eng_stag.requests):
        assert a.tokens == b.tokens
        np.testing.assert_allclose(a.token_conf, b.token_conf, rtol=1e-5)
