"""Production cascade server: packing, accounting, δ-from-budget."""
import numpy as np
import pytest

from repro.core.server import (CascadeServer, ServingMember,
                               delta_for_escalation_rate)


def _member(name, cost, conf_fn, tag):
    def generate(prompts):
        B = prompts.shape[0]
        out = np.full((B, 4), tag, np.int32)
        conf = conf_fn(prompts)
        return out, conf

    return ServingMember(name, generate, cost)


def test_packed_escalation_and_accounting():
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 100, (32, 8))
    # fast member confidence keyed off prompt parity: half escalate
    fast = _member("fast", 1.0,
                   lambda p: np.where(p[:, 0] % 2 == 0, 0.9, 0.1), tag=1)
    exp = _member("exp", 10.0, lambda p: np.ones(p.shape[0]), tag=2)
    srv = CascadeServer([fast, exp], deltas=[0.5])
    out, handled = srv.serve(prompts)

    esc = prompts[:, 0] % 2 == 1
    np.testing.assert_array_equal(handled, esc.astype(np.int32))
    assert (out[esc] == 2).all() and (out[~esc] == 1).all()

    s = srv.summary()
    n_esc = int(esc.sum())
    want_cost = (32 * 1.0 + n_esc * 10.0) / 32
    assert s["cost_per_request"] == pytest.approx(want_cost)
    assert s["escalation_rates"][0] == pytest.approx(n_esc / 32)


def test_three_member_chain():
    prompts = np.arange(24).reshape(24, 1)
    m1 = _member("s", 1.0, lambda p: (p[:, 0] % 3 > 0) * 1.0, tag=1)
    m2 = _member("m", 5.0, lambda p: (p[:, 0] % 2 > 0) * 1.0, tag=2)
    m3 = _member("l", 20.0, lambda p: np.ones(p.shape[0]), tag=3)
    srv = CascadeServer([m1, m2, m3], deltas=[0.5, 0.5])
    out, handled = srv.serve(prompts)
    # escalate from m1 where p%3==0; of those, escalate from m2 where p%2==0
    esc1 = prompts[:, 0] % 3 == 0
    esc2 = esc1 & (prompts[:, 0] % 2 == 0)
    np.testing.assert_array_equal(handled == 2, esc2)
    np.testing.assert_array_equal(handled == 1, esc1 & ~esc2)
    # gate stats: second gate only saw escalated-from-first traffic
    assert srv.stats.gates[1].seen == int(esc1.sum())


def test_stats_accumulate_across_batches():
    fast = _member("fast", 1.0, lambda p: np.zeros(p.shape[0]), tag=1)
    exp = _member("exp", 3.0, lambda p: np.ones(p.shape[0]), tag=2)
    srv = CascadeServer([fast, exp], deltas=[0.5])
    for _ in range(3):
        srv.serve(np.zeros((4, 2), np.int32))
    assert srv.stats.requests == 12
    assert srv.stats.gates[0].escalated == 12     # conf 0 <= δ always
    assert srv.summary()["cost_per_request"] == pytest.approx(4.0)


def test_empty_prompt_batch():
    """Regression: serve([]) used to crash with outputs=None; it must
    return an empty outputs/handled_by pair and leave stats untouched."""
    fast = _member("fast", 1.0, lambda p: np.ones(p.shape[0]), tag=1)
    exp = _member("exp", 10.0, lambda p: np.ones(p.shape[0]), tag=2)
    srv = CascadeServer([fast, exp], deltas=[0.5])
    out, handled = srv.serve(np.zeros((0, 8), np.int32))
    assert out.shape[0] == 0 and handled.shape == (0,)
    assert srv.stats.requests == 0 and srv.stats.cost == 0.0
    assert srv.stats.gates[0].seen == 0


def test_delta_for_escalation_rate():
    confs = np.linspace(0, 1, 101)
    d = delta_for_escalation_rate(confs, 0.3)
    assert 0.28 <= d <= 0.32
    # realized rate on the calibration traffic ~ target
    assert abs((confs <= d).mean() - 0.3) < 0.02
    assert delta_for_escalation_rate([], 0.5) == 0.5
