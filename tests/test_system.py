"""End-to-end behaviour tests: the paper's pipeline on synthetic data.

The full experiment (train zoo -> calibrate -> sweep δ -> Eq 2/7 metrics)
at miniature scale, asserting the paper's *qualitative* claims:

  1. cascade accuracy >= expensive-model accuracy at the chosen δ
     (the §3 constraint with ε=0),
  2. cascade cost < always-expensive cost,
  3. LtC training produces a usable conf signal (separates fast-right
     from fast-wrong-and-exp-right).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cascade, losses, thresholds
from repro.core import confidence as conf_lib
from repro.data.synthetic import teacher_task
from repro.models import classifier as clf


@pytest.fixture(scope="module")
def tiny_world():
    ds = teacher_task(num_samples=12000, num_classes=10, dim=12,
                      obs_noise=0.25, seed=1)
    tr, va, te = ds.split((0.8, 0.1, 0.1), seed=1)
    key = jax.random.PRNGKey(0)
    fast_cfg = clf.MLPConfig("fast", 32, 1, 10, 12)
    exp_cfg = clf.MLPConfig("exp", 128, 4, 10, 12)
    exp_p = clf.train_classifier(exp_cfg, jnp.asarray(tr.x),
                                 jnp.asarray(tr.y), key=key, epochs=8,
                                 lr=0.03)
    exp_logits_tr, _ = clf.predict(exp_p, jnp.asarray(tr.x))
    fast_p = clf.train_classifier(fast_cfg, jnp.asarray(tr.x),
                                  jnp.asarray(tr.y), key=key, epochs=8,
                                  lr=0.03, exp_logits=exp_logits_tr,
                                  ltc_w=1.0)
    return dict(tr=tr, va=va, te=te, fast_cfg=fast_cfg, exp_cfg=exp_cfg,
                fast_p=fast_p, exp_p=exp_p)


def _eval(w, split):
    fl, _ = clf.predict(w["fast_p"], jnp.asarray(split.x))
    el, _ = clf.predict(w["exp_p"], jnp.asarray(split.x))
    conf = conf_lib.max_prob(fl)
    fc = np.asarray(losses.correct(fl, jnp.asarray(split.y)))
    ec = np.asarray(losses.correct(el, jnp.asarray(split.y)))
    return np.asarray(conf), fc, ec


def test_cascade_meets_paper_constraint(tiny_world):
    w = tiny_world
    costs = [w["fast_cfg"].macs, w["exp_cfg"].macs]
    conf_va, fc_va, ec_va = _eval(w, w["va"])
    delta, _, _ = thresholds.best_accuracy_delta(conf_va, fc_va, ec_va, costs)

    conf_te, fc_te, ec_te = _eval(w, w["te"])
    acc, cost, n_exp = cascade.two_element_metrics(
        jnp.asarray(conf_te), jnp.asarray(fc_te), jnp.asarray(ec_te),
        costs[0], costs[1], delta)
    acc_exp = ec_te.mean()
    # paper §3 constraint (ε=0, small-sample slack two σ)
    sigma = np.sqrt(acc_exp * (1 - acc_exp) / len(fc_te))
    assert float(acc) >= acc_exp - 2 * sigma
    # cost strictly below always-escalate
    assert float(cost) < costs[0] + costs[1]
    assert 0 <= float(n_exp) <= len(fc_te)


def test_ltc_confidence_separates_cases(tiny_world):
    """Paper Fig 5: conf should be high when the fast model is right, and
    (relatively) low when only the expensive model is right."""
    w = tiny_world
    conf, fc, ec = _eval(w, w["te"])
    fast_right = conf[fc == 1]
    exp_only = conf[(fc == 0) & (ec == 1)]
    if len(exp_only) > 10:
        assert fast_right.mean() > exp_only.mean()


def test_expensive_beats_fast(tiny_world):
    w = tiny_world
    _, fc, ec = _eval(w, w["te"])
    assert ec.mean() > fc.mean() + 0.01
