"""Cascade executor + metrics (Eqs 1, 2, 7) including property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp_compat import given, settings, st  # hypothesis or skip-stub

from repro.core import cascade, thresholds


def _mk(n, seed=0, m=2):
    rng = np.random.default_rng(seed)
    confs = rng.random((m - 1, n)).astype(np.float32)
    corrects = (rng.random((m, n)) < np.linspace(0.6, 0.9, m)[:, None])
    costs = np.cumsum(rng.random(m).astype(np.float32) + 0.5)
    return confs, corrects.astype(np.float32), costs


def test_delta_zero_never_escalates():
    confs, corrects, costs = _mk(256)
    out = cascade.evaluate_cascade(confs, corrects, costs, np.array([[0.0]]))
    # conf > 0 for all => everything stops at the fast model (conf>δ)
    assert float(out["cost"][0]) == pytest.approx(costs[0], rel=1e-6)
    assert float(out["acc"][0]) == pytest.approx(corrects[0].mean(), rel=1e-6)


def test_delta_one_always_escalates():
    confs, corrects, costs = _mk(256)
    out = cascade.evaluate_cascade(confs, corrects, costs, np.array([[1.0]]))
    assert float(out["cost"][0]) == pytest.approx(costs.sum(), rel=1e-6)
    assert float(out["acc"][0]) == pytest.approx(corrects[1].mean(), rel=1e-6)


def test_eq1_eq2_eq7_two_element():
    confs, corrects, costs = _mk(512, seed=1)
    delta = 0.42
    acc, cost, n_exp = cascade.two_element_metrics(
        jnp.asarray(confs[0]), jnp.asarray(corrects[0]),
        jnp.asarray(corrects[1]), costs[0], costs[1], delta)
    stop = confs[0] > delta
    acc_manual = np.mean(np.where(stop, corrects[0], corrects[1]))
    n_exp_manual = np.sum(~stop)
    cost_manual = costs[0] + n_exp_manual / 512 * costs[1]
    assert float(acc) == pytest.approx(acc_manual, rel=1e-6)
    assert float(n_exp) == pytest.approx(n_exp_manual)
    assert float(cost) == pytest.approx(cost_manual, rel=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.0, 1.0))
def test_property_cost_monotone_in_delta(seed, delta):
    """Raising δ never lowers N^exp (Eq 1 monotonicity) and never lowers
    MACs^casc."""
    confs, corrects, costs = _mk(128, seed=seed % 1000)
    d2 = min(1.0, delta + 0.25)
    out = cascade.evaluate_cascade(confs, corrects, costs,
                                   np.array([[delta], [d2]]))
    assert float(out["n_exp"][1, 0]) >= float(out["n_exp"][0, 0]) - 1e-6
    assert float(out["cost"][1]) >= float(out["cost"][0]) - 1e-6


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_acc_bounded_by_oracle(seed):
    """Cascade accuracy can never exceed the per-sample oracle (either
    member right) nor drop below zero."""
    confs, corrects, costs = _mk(128, seed=seed % 1000)
    deltas = np.linspace(0, 1, 11)[:, None]
    out = cascade.evaluate_cascade(confs, corrects, costs, deltas)
    oracle = np.maximum(corrects[0], corrects[1]).mean()
    assert np.all(np.asarray(out["acc"]) <= oracle + 1e-6)
    assert np.all(np.asarray(out["acc"]) >= -1e-6)


def test_three_element_cascade_accounting():
    confs, corrects, costs = _mk(256, seed=2, m=3)
    out = cascade.evaluate_cascade(confs, corrects, costs,
                                   np.array([[0.5, 0.5]]))
    # manual
    active = np.ones(256)
    acc = np.zeros(256)
    cost = 0.0
    for m in range(3):
        cost += active.mean() * costs[m]
        if m < 2:
            stop = active * (confs[m] > 0.5)
            acc += stop * corrects[m]
            active = active - stop
        else:
            acc += active * corrects[m]
    assert float(out["acc"][0]) == pytest.approx(acc.mean(), rel=1e-6)
    assert float(out["cost"][0]) == pytest.approx(cost, rel=1e-6)


def test_threshold_policies():
    confs, corrects, costs = _mk(1024, seed=3)
    d, acc, cost = thresholds.best_accuracy_delta(
        confs[0], corrects[0], corrects[1], costs)
    assert 0.0 <= d <= 1.0
    # paper constraint policy
    d2, acc2, cost2, feasible = thresholds.min_cost_delta(
        confs[0], corrects[0], corrects[1], costs,
        acc_target=corrects[1].mean())
    if feasible:
        assert acc2 >= corrects[1].mean() - 1e-6
        assert cost2 <= costs.sum() + 1e-6


def test_online_executor_matches_offline():
    rng = np.random.default_rng(4)
    n, k = 64, 6
    logits_fast = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 2)
    logits_exp = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 2)
    labels = jnp.asarray(rng.integers(0, k, n))
    delta = 0.5
    ex = cascade.CascadeExecutor(
        [cascade.Member("fast", 1.0, lambda b: logits_fast),
         cascade.Member("exp", 10.0, lambda b: logits_exp)], [delta])
    preds, info = ex(None)
    conf = np.max(jax.nn.softmax(logits_fast, -1), -1)
    esc = conf <= delta
    want = np.where(esc, np.argmax(logits_exp, -1), np.argmax(logits_fast, -1))
    np.testing.assert_array_equal(preds, want)
    np.testing.assert_allclose(info["cost"],
                               1.0 + esc.astype(np.float32) * 10.0)
