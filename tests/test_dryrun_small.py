"""Small-mesh dry-run integration tests.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single real device (the production
512-device forcing lives only in repro.launch.dryrun).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("shape_name,arch", [
    ("train_4k", "gemma3-1b"),
    ("decode_32k", "rwkv6-3b"),
    ("prefill_32k", "granite-moe-3b-a800m"),
])
def test_small_mesh_lower_compile(shape_name, arch):
    """Lower+compile a REDUCED config on a 2x4 mesh: proves the sharding
    rules produce a coherent GSPMD program end to end."""
    out = _run(f"""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, smoke_variant
        from repro.models import sharding as sharding_lib
        from repro.launch.mesh import make_test_mesh
        from repro.launch.shapes import SHAPES, input_specs
        from repro.launch import steps as steps_lib
        from repro.models import params as params_lib

        mesh = make_test_mesh(8)
        cfg = get_config("{arch}", "smoke")
        # reduced shape in the same kind as {shape_name}
        import repro.launch.shapes as shp
        kind = SHAPES["{shape_name}"].kind
        shp.SHAPES["tiny"] = shp.InputShape("tiny", 64, 8, kind)
        pshapes = params_lib.param_shapes(cfg, dtype=jnp.float32, mesh=mesh)
        inputs = input_specs(cfg, "tiny", mesh, dtype=jnp.float32)
        with sharding_lib.set_mesh(mesh):
            if kind == "train":
                step, opt = steps_lib.make_train_step(cfg)
                osh = steps_lib.opt_state_shapes(opt, cfg, mesh)
                lowered = jax.jit(step).lower(pshapes, osh, inputs)
            elif kind == "prefill":
                lowered = jax.jit(steps_lib.make_prefill_step(cfg)).lower(pshapes, inputs)
            else:
                lowered = jax.jit(steps_lib.make_serve_step(cfg)).lower(
                    pshapes, inputs["token"], inputs["pos"], inputs["cache"])
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # jax 0.4.x returns a list
            cost = cost[0]
        assert cost.get("flops", 0) > 0
        print("OK", compiled.memory_analysis().argument_size_in_bytes)
    """)
    assert "OK" in out


def test_small_mesh_real_train_step_runs():
    """Actually execute a sharded train step on 8 host devices and check
    loss finiteness — beyond lowering, the program runs."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import sharding as sharding_lib
        from repro.launch.mesh import make_test_mesh
        from repro.launch import steps as steps_lib
        from repro.models import init_params, params as params_lib
        from repro.data import shard_batch

        mesh = make_test_mesh(8)
        cfg = get_config("granite-moe-3b-a800m", "smoke")
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key, jnp.float32)
        specs = params_lib.param_specs(cfg, mesh)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, jax.sharding.NamedSharding(mesh, s)),
            params, specs, is_leaf=lambda x: hasattr(x, 'shape') and not isinstance(x, dict))
        step, opt = steps_lib.make_train_step(cfg, lr=1e-2)
        state = opt.init(params)
        batch = {"tokens": np.random.randint(0, cfg.vocab_size, (8, 64)).astype(np.int32)}
        batch = shard_batch(batch, mesh)
        with sharding_lib.set_mesh(mesh):
            params, state, m = jax.jit(step)(params, state, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss), loss
        print("OK loss", loss)
    """)
    assert "OK loss" in out


def test_collective_parser_sees_collectives():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_test_mesh
        from repro.launch.hlo import collective_stats

        mesh = make_test_mesh(8)
        w = jax.ShapeDtypeStruct((256, 256), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None, "model")))
        x = jax.ShapeDtypeStruct((16, 256), jnp.float32,
                                 sharding=NamedSharding(mesh, P("data", None)))
        f = lambda w, x: jnp.sum((x @ w) ** 2)
        compiled = jax.jit(f).lower(w, x).compile()
        st = collective_stats(compiled.as_text())
        assert st.total_raw_bytes > 0, st
        assert "all-reduce" in st.bytes_by_op
        print("OK", st.bytes_by_op)
    """)
    assert "OK" in out
