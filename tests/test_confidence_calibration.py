"""Confidence scores, calibration baselines, ECE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp_compat import given, settings, st  # hypothesis or skip-stub

from repro.core import calibration, confidence, losses


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 50))
def test_property_scores_in_range(seed, k):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (16, k)) * 3
    mp = confidence.max_prob(logits)
    assert np.all(mp >= 1.0 / k - 1e-6) and np.all(mp <= 1.0 + 1e-6)
    ec = confidence.entropy_confidence(logits)
    assert np.all(ec >= -1e-5) and np.all(ec <= 1.0 + 1e-6)
    mg = confidence.margin(logits)
    assert np.all(mg >= -1e-6) and np.all(mg <= 1.0 + 1e-6)


def test_temperature_scaling_recovers_temperature():
    """Fitting T on logits that were miscalibrated by a known factor
    should recover ~that factor."""
    key = jax.random.PRNGKey(0)
    n, k = 4000, 10
    true_logits = jax.random.normal(key, (n, k)) * 2.0
    labels = jax.random.categorical(jax.random.PRNGKey(1), true_logits)
    overconfident = true_logits * 3.0         # T* = 3
    t = calibration.fit_temperature(overconfident, labels, steps=400, lr=0.05)
    assert 2.0 < t < 4.5


def test_temperature_scaling_preserves_argmax_and_ranking():
    key = jax.random.PRNGKey(2)
    logits = jax.random.normal(key, (64, 12)) * 2
    for t in (0.5, 2.0, 10.0):
        np.testing.assert_array_equal(jnp.argmax(logits, -1),
                                      jnp.argmax(logits / t, -1))


def test_conf_head_learns_correctness():
    """ConfNet head trained on features must separate right from wrong."""
    key = jax.random.PRNGKey(3)
    n, d, k = 2000, 16, 5
    feats = jax.random.normal(key, (n, d))
    w = jax.random.normal(jax.random.PRNGKey(4), (d, k))
    logits = feats @ w
    labels = jax.random.categorical(jax.random.PRNGKey(5), logits * 2)
    head = calibration.fit_conf_head(key, feats, logits, labels,
                                     kind="confnet", steps=300)
    conf = calibration.conf_head_apply(head, feats)
    correct = np.asarray(losses.correct(logits, labels))
    assert conf[correct == 1].mean() > conf[correct == 0].mean()


def test_ece_perfect_and_worst():
    conf = jnp.array([0.9] * 100)
    correct = jnp.array([1.0] * 90 + [0.0] * 10)
    assert calibration.ece(conf, correct) == pytest.approx(0.0, abs=1e-6)
    correct_bad = jnp.zeros(100)
    assert calibration.ece(conf, correct_bad) == pytest.approx(0.9, abs=1e-6)


def test_sequence_confidence_reductions():
    tc = jnp.array([[0.9, 0.5, 0.7], [0.2, 0.9, 0.9]])
    assert confidence.sequence_confidence(tc, reduce="mean").shape == (2,)
    mn = confidence.sequence_confidence(tc, reduce="min")
    np.testing.assert_allclose(mn, [0.5, 0.2])
    pr = confidence.sequence_confidence(tc, reduce="prod")
    np.testing.assert_allclose(pr, [0.9 * 0.5 * 0.7, 0.2 * 0.9 * 0.9],
                               rtol=1e-5)
