"""Allocator-invariant property suite for the refcounted prefix cache.

A white-box model checker (:func:`check_invariants`) audits the full
allocator state after every operation:

  * refcounts are positive for live blocks and zero elsewhere, and every
    count equals the references actually outstanding (row page-table
    mappings + prefix-index entries);
  * no block is simultaneously free and referenced — the free lists, the
    withheld (shrink) lists, and the live set partition the arena;
  * per-shard conservation: ``free + live + withheld`` equals the
    shard's usable span (minus the null block on shard 0);
  * page tables mirror the row block lists exactly, shared (read-only)
    pages form a prefix of each row, and the null block never leaks.

A seeded fuzzer then drives random interleavings of admission
(bind with/without a prefix match, including unaligned copy-on-write
binds), chunked growth, publishing, release/preemption, LRU reclaim,
and fault-injection shrink/unshrink against the checker.  The
deterministic parametrized runs execute everywhere; the
hypothesis-driven layer (via :mod:`tests._hyp_compat`) widens the same
driver to 200 random interleavings in CI, where hypothesis is
installed.

Deterministic regression tests at the bottom pin the guard-message
contract: double-free, share-after-free, plain double release, and the
"already released but its blocks are still shared" case are distinct
errors (the last one tells the caller nothing leaked).
"""
import random
from collections import Counter

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.slots import (NULL_BLOCK, BlockAllocator, PrefixEntry,
                                 TierSlotPool)
from tests._hyp_compat import given, settings, st

BS = 4          # block size
CHUNK = 8       # prefix_chunk
MAX_SEQ = 32
CAPACITY = 4


@pytest.fixture(scope="module")
def cfg():
    return get_config("gemma3-1b", "smoke")


def make_pool(cfg, shards=1, num_blocks=None, oversubscribe=False):
    if num_blocks is None:
        full = CAPACITY * (MAX_SEQ // BS) + 1
        num_blocks = (full // 2 + shards) if oversubscribe else full
        num_blocks += (-num_blocks) % shards
    return TierSlotPool(cfg, CAPACITY, MAX_SEQ, block_size=BS,
                        num_blocks=num_blocks, data_shards=shards,
                        prefix_chunk=CHUNK)


# -- the model checker -------------------------------------------------------


def check_invariants(pool: TierSlotPool) -> None:
    alloc = pool.blocks
    free, withheld = set(), set()
    for s in range(alloc.shards):
        fs, rs = alloc._free[s], alloc._reserved[s]
        assert len(set(fs)) == len(fs), f"shard {s} free list has dupes"
        assert len(set(rs)) == len(rs), f"shard {s} reserved list has dupes"
        lo, hi = s * alloc._span, (s + 1) * alloc._span
        assert all(lo <= b < hi for b in fs + rs), \
            f"shard {s} holds out-of-range block ids"
        free |= set(fs)
        withheld |= set(rs)
    live = set(alloc._used)
    # the three states partition the arena; the null block is in none
    assert not (free & live), "block both free and live"
    assert not (free & withheld), "block both free and withheld"
    assert not (withheld & live), "block both withheld and live"
    assert NULL_BLOCK not in free | live | withheld, "null block escaped"
    for s in range(alloc.shards):
        usable = alloc._span - (1 if s == 0 else 0)
        assert alloc.free_in(s) + alloc.used_in(s) + alloc.reserved_in(s) \
            == usable, f"shard {s} conservation violated"
    # refcount bookkeeping: live blocks only, all positive
    assert set(alloc._refcount) == live
    assert all(rc >= 1 for rc in alloc._refcount.values())
    # every reference is accounted for: rows + index entries
    row_refs = Counter(b for blocks in pool._row_blocks for b in blocks)
    idx_refs = Counter(b for shard_idx in pool._index
                       for ent in shard_idx.values() for b in ent.blocks)
    assert dict(idx_refs) == pool._index_refs
    for b in live:
        assert alloc.refcount(b) == row_refs[b] + idx_refs[b], \
            f"block {b}: rc {alloc.refcount(b)} != " \
            f"{row_refs[b]} row refs + {idx_refs[b]} index refs"
    for b in free | withheld:
        assert row_refs[b] == 0 and idx_refs[b] == 0, \
            f"non-live block {b} is referenced"
    assert alloc.num_shared == sum(
        1 for b in live if alloc._refcount[b] >= 2)
    # page tables mirror the row block lists; shared pages are a prefix
    for slot in range(pool.capacity):
        blocks = pool._row_blocks[slot]
        assert pool._row_shared[slot] <= len(blocks)
        for j in range(pool.pages_per_row):
            want = blocks[j] if j < len(blocks) else NULL_BLOCK
            assert pool.page_table[slot, j] == want, \
                f"page_table[{slot},{j}] = {pool.page_table[slot, j]}, " \
                f"row blocks say {want}"
        if blocks:
            assert slot in pool._order
        else:
            assert slot not in pool._order


# -- the fuzz driver ---------------------------------------------------------


class Driver:
    """One random interleaving of pool operations, invariant-checked
    after every step.  Prompts draw from a tiny base set so prefix
    matches (and therefore sharing, CoW, and eviction pressure) actually
    occur."""

    def __init__(self, pool: TierSlotPool, rng: random.Random):
        self.pool = pool
        self.rng = rng
        # rows: slot -> (prompt, prefill progress in tokens)
        self.rows = {}
        self.bases = [np.arange(100 * (i + 1), 100 * (i + 1) + MAX_SEQ,
                                dtype=np.int32) for i in range(2)]

    def _prompt(self):
        base = self.rng.choice(self.bases)
        plen = self.rng.randint(2, MAX_SEQ - 1)
        p = base[:plen].copy()
        if self.rng.random() < 0.4:   # unique suffix past a shared head
            cut = self.rng.randint(1, plen)
            p[cut:] = self.rng.randrange(10_000) + np.arange(plen - cut)
        return p

    def op_admit(self):
        free = [s for s in range(self.pool.capacity) if s not in self.rows]
        if not free:
            return
        slot = self.rng.choice(free)
        shard = self.pool.shard_of(slot)
        prompt = self._prompt()
        plen = len(prompt)
        cached, blks = self.pool.match_prefix(prompt, shard)
        span = cached + min(CHUNK, plen - cached)
        if cached and self.pool.can_admit(span, shard, cached=cached,
                                          prefix_blocks=blks):
            self.pool.bind(slot, span, row_tokens=plen,
                           prefix=(cached, blks))
            self.rows[slot] = (prompt, span)
        elif self.pool.can_admit(min(CHUNK, plen), shard):
            # can_admit True must mean bind succeeds (deadlock freedom)
            self.pool.bind(slot, min(CHUNK, plen), row_tokens=plen)
            self.rows[slot] = (prompt, min(CHUNK, plen))

    def op_admit_unaligned(self):
        """Copy-on-write path: bind against a hand-picked cached length
        that splits a block (the aligned publisher never emits these)."""
        free = [s for s in range(self.pool.capacity) if s not in self.rows]
        shard_entries = [(sh, ent) for sh in range(self.pool.data_shards)
                         for ent in self.pool._index[sh].values()
                         if ent.ntokens > BS]
        if not free or not shard_entries:
            return
        shard, ent = self.rng.choice(shard_entries)
        slots = [s for s in free if self.pool.shard_of(s) == shard]
        if not slots:
            return
        slot = self.rng.choice(slots)
        cached = ent.ntokens - self.rng.randint(1, BS - 1)  # splits a block
        prompt = np.concatenate([
            np.zeros(cached, np.int32),
            self.rng.randrange(10_000) + np.arange(4, dtype=np.int32)])
        plen = len(prompt)
        span = cached + min(CHUNK, plen - cached)
        if self.pool.can_admit(span, shard, cached=cached,
                               prefix_blocks=ent.blocks):
            before = self.pool.prefix_cow_copies
            self.pool.bind(slot, span, row_tokens=plen,
                           prefix=(cached, list(ent.blocks)))
            assert self.pool.prefix_cow_copies == before + 1
            self.rows[slot] = (prompt, span)

    def op_grow(self):
        rows = [(s, p, pos) for s, (p, pos) in self.rows.items()
                if pos < len(p)]
        if not rows:
            return
        slot, prompt, pos = self.rng.choice(rows)
        step = min(CHUNK, len(prompt) - pos)
        if self.pool.ensure_blocks(slot, pos + step - 1):
            self.rows[slot] = (prompt, pos + step)

    def op_publish(self):
        if not self.rows:
            return
        slot = self.rng.choice(list(self.rows))
        prompt, pos = self.rows[slot]
        self.pool.publish_prefix(slot, prompt, pos)

    def op_release(self):
        if not self.rows:
            return
        slot = self.rng.choice(list(self.rows))
        self.pool.release(slot)
        del self.rows[slot]

    def op_release_unbound(self):
        unbound = [s for s in range(self.pool.capacity)
                   if s not in self.rows]
        if not unbound:
            return
        with pytest.raises(ValueError, match="already released|not bound"):
            self.pool.release(self.rng.choice(unbound))

    def op_double_free(self):
        alloc = self.pool.blocks
        shard = self.rng.randrange(alloc.shards)
        if not alloc._free[shard]:
            return
        with pytest.raises(ValueError, match="double free"):
            alloc.free(self.rng.choice(alloc._free[shard]))

    def op_shrink(self):
        self.pool.shrink(self.rng.randint(1, 4))

    def op_unshrink(self):
        self.pool.unshrink()

    def op_reclaim(self):
        shard = self.rng.randrange(self.pool.data_shards)
        want = self.pool.blocks.free_in(shard) + self.rng.randint(1, 3)
        self.pool._reclaim(shard, want)

    OPS = (op_admit, op_admit, op_grow, op_grow, op_publish, op_publish,
           op_release, op_admit_unaligned, op_release_unbound,
           op_double_free, op_shrink, op_unshrink, op_reclaim)

    def run(self, steps: int):
        check_invariants(self.pool)
        for _ in range(steps):
            self.rng.choice(self.OPS)(self)
            check_invariants(self.pool)
        # drain: every row releases cleanly and sharing ends at 0 rows
        for slot in list(self.rows):
            self.pool.release(slot)
            del self.rows[slot]
            check_invariants(self.pool)


@pytest.mark.parametrize("shards", (1, 2))
@pytest.mark.parametrize("seed", range(6))
def test_fuzz_interleavings(cfg, shards, seed):
    pool = make_pool(cfg, shards=shards, oversubscribe=seed % 2 == 1)
    Driver(pool, random.Random(seed)).run(steps=60)


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.sampled_from([1, 2]), st.booleans())
def test_fuzz_interleavings_hypothesis(cfg, seed, shards, oversub):
    """The CI layer: 200 hypothesis-driven interleavings of the same
    driver (skipped when hypothesis is not installed — the parametrized
    deterministic runs above still execute)."""
    pool = make_pool(cfg, shards=shards, oversubscribe=oversub)
    Driver(pool, random.Random(seed)).run(steps=40)


# -- deterministic refcount / sharing unit tests -----------------------------


def test_refcount_lifecycle():
    alloc = BlockAllocator(8)
    b = alloc.alloc(0)
    assert alloc.refcount(b) == 1 and alloc.num_shared == 0
    alloc.ref(b)
    assert alloc.refcount(b) == 2 and alloc.num_shared == 1
    assert alloc.shared_high_water == 1
    alloc.free(b)                    # drop to 1: still live
    assert alloc.refcount(b) == 1 and alloc.num_shared == 0
    assert b in alloc._used and b not in alloc._free[0]
    alloc.free(b)                    # drop to 0: back on the free list
    assert alloc.refcount(b) == 0
    assert b in alloc._free[0]


def test_ref_of_free_block_raises():
    alloc = BlockAllocator(8)
    b = alloc.alloc(0)
    alloc.free(b)
    with pytest.raises(ValueError, match="cannot share"):
        alloc.ref(b)
    with pytest.raises(ValueError, match="cannot share"):
        alloc.ref(NULL_BLOCK)


def test_double_free_message():
    alloc = BlockAllocator(8)
    b = alloc.alloc(0)
    alloc.free(b)
    with pytest.raises(ValueError, match=rf"block {b} is not allocated "
                                         r"\(double free\?\)"):
        alloc.free(b)


def test_prefix_boundaries_align_down(cfg):
    pool = make_pool(cfg)            # chunk 8, block 4: already aligned
    assert pool._prefix_boundaries(24) == [8, 16, 24]
    assert pool._prefix_boundaries(7) == []
    pool.prefix_chunk = 6            # unaligned chunk rounds down
    assert pool._prefix_boundaries(24) == [4, 12, 16, 24]


def test_match_caps_below_full_prompt(cfg):
    """A fully cached prompt still computes its last token's logits: the
    match is capped at len(prompt) - 1, so an exact-length hit misses."""
    pool = make_pool(cfg)
    prompt = np.arange(50, 58, dtype=np.int32)   # 8 tokens == one chunk
    pool.bind(0, 8, row_tokens=12)
    pool.publish_prefix(0, prompt, 8)
    assert pool.match_prefix(prompt, 0) == (0, [])          # capped
    longer = np.arange(50, 62, dtype=np.int32)
    n, blks = pool.match_prefix(longer, 0)
    assert n == 8 and len(blks) == 2                        # genuine hit


def test_publish_and_share_refcounts(cfg):
    pool = make_pool(cfg)
    prompt = np.arange(0, 20, dtype=np.int32)
    pool.bind(0, 8, row_tokens=24)
    pool.publish_prefix(0, prompt, 8)
    n, blks = pool.match_prefix(prompt, 0)
    assert (n, len(blks)) == (8, 2)
    assert all(pool.blocks.refcount(b) == 2 for b in blks)  # row + index
    pool.bind(1, 8 + CHUNK, row_tokens=24, prefix=(8, blks))
    assert pool.shared_pages(1) == 2
    assert all(pool.blocks.refcount(b) == 3 for b in blks)
    pool.release(0)                  # publisher leaves; blocks stay live
    assert all(pool.blocks.refcount(b) == 2 for b in blks)
    assert pool.match_prefix(prompt, 0)[0] == 8
    pool.release(1)
    assert all(pool.blocks.refcount(b) == 1 for b in blks)  # index only
    assert pool.evictable_in(0) == len(set(blks))


def test_release_errors_distinguish_shared_from_double(cfg):
    """Satellite regression: the double-release guard must say *which*
    failure happened — plain double release vs an earlier release whose
    blocks remain live through shared references (not a leak)."""
    pool = make_pool(cfg)
    with pytest.raises(ValueError, match=r"slot 3 is not bound "
                                         r"\(double release\?\)"):
        pool.release(3)
    prompt = np.arange(0, 20, dtype=np.int32)
    pool.bind(0, 8, row_tokens=24)
    pool.publish_prefix(0, prompt, 8)           # index shares row 0's blocks
    pool.release(0)
    with pytest.raises(ValueError, match=r"slot 0 is already released; "
                                         r"2 of its blocks remain live via "
                                         r"shared references"):
        pool.release(0)
    # a row with no shared blocks keeps the plain message
    pool.bind(1, 4, row_tokens=8)
    pool.release(1)
    with pytest.raises(ValueError, match=r"slot 1 is not bound "
                                         r"\(double release\?\)"):
        pool.release(1)


def test_lru_eviction_order_and_counters(cfg):
    pool = make_pool(cfg, num_blocks=33)
    p1 = np.arange(0, 20, dtype=np.int32)
    p2 = np.arange(40, 60, dtype=np.int32)
    pool.bind(0, 8, row_tokens=24)
    pool.publish_prefix(0, p1, 8)
    pool.bind(1, 8, row_tokens=24)
    pool.publish_prefix(1, p2, 8)
    pool.match_prefix(p1, 0)                     # p1 becomes most recent
    pool.release(0)
    pool.release(1)
    assert pool.prefix_index_entries(0) == 2
    # force one eviction: p2's entry (least recently used) must go first
    pool._reclaim(0, pool.blocks.free_in(0) + 2)
    assert pool.prefix_evictions == 1
    assert pool.match_prefix(p2, 0) == (0, [])
    assert pool.match_prefix(p1, 0)[0] == 8


def test_eviction_keeps_row_shared_blocks(cfg):
    """Reclaim may only return blocks whose every reference is an index
    reference: entries shared with a live row lose the entry but free no
    blocks."""
    pool = make_pool(cfg, num_blocks=33)
    prompt = np.arange(0, 20, dtype=np.int32)
    pool.bind(0, 8, row_tokens=24)
    pool.publish_prefix(0, prompt, 8)
    n, blks = pool.match_prefix(prompt, 0)
    pool.bind(1, 8 + CHUNK, row_tokens=24, prefix=(n, blks))
    pool.release(0)
    free_before = pool.blocks.free_in(0)
    assert pool.evictable_in(0) == 0             # row 1 still maps them
    pool._reclaim(0, free_before + 1)            # drops the entry...
    assert pool.prefix_index_entries(0) == 0
    assert pool.blocks.free_in(0) == free_before  # ...but frees nothing
    assert all(pool.blocks.refcount(b) == 1 for b in blks)
    check_invariants(pool)


def test_shrink_takes_only_unreferenced_blocks(cfg):
    pool = make_pool(cfg)
    prompt = np.arange(0, 20, dtype=np.int32)
    pool.bind(0, 8, row_tokens=24)
    pool.publish_prefix(0, prompt, 8)
    took = pool.shrink(6)
    assert took > 0
    withheld = set(pool.blocks._reserved[0])
    live = set(pool.blocks._refcount)
    assert not (withheld & live)
    check_invariants(pool)
    pool.unshrink()
    check_invariants(pool)


def test_cow_copy_duplicates_device_blocks(cfg):
    """_copy_blocks must byte-copy every paged leaf: fill the source
    block with a sentinel, copy, and read the destination back."""
    import jax.numpy as jnp

    pool = make_pool(cfg)
    src, dst = pool.blocks.alloc(0), pool.blocks.alloc(0)

    def fill(full, meta):
        kind, ax = meta
        if kind != "paged":
            return full
        idx = [slice(None)] * full.ndim
        idx[ax] = src
        return full.at[tuple(idx)].set(jnp.asarray(1.25, full.dtype))

    pool.cache = jax.tree.map(fill, pool.cache, pool._meta)
    pool._copy_blocks([src], [dst])
    checked = 0
    for leaf, meta in zip(
            jax.tree.leaves(pool.cache),
            jax.tree.flatten(pool._meta,
                             is_leaf=lambda x: isinstance(x, tuple))[0]):
        if meta[0] != "paged":
            continue
        idx = [slice(None)] * leaf.ndim
        idx[meta[1]] = dst
        np.testing.assert_allclose(np.asarray(leaf[tuple(idx)]), 1.25)
        checked += 1
    assert checked > 0


def test_unaligned_prefix_entry_triggers_cow(cfg):
    """An index entry whose boundary splits a block (never produced by
    the aligned publisher, but legal) must copy the split block before
    the new row can write into it."""
    pool = make_pool(cfg)
    prompt = np.arange(0, 20, dtype=np.int32)
    pool.bind(0, 8, row_tokens=24)
    pool.publish_prefix(0, prompt, 8)
    blocks = [int(pool.page_table[0, 0]), int(pool.page_table[0, 1])]
    for b in blocks:                 # hand-built unaligned entry
        pool.blocks.ref(b)
        pool._index_refs[b] = pool._index_refs.get(b, 0) + 1
    pool._index[0][pool._prefix_key(prompt, 6)] = \
        PrefixEntry(6, list(blocks), 999)
    check_invariants(pool)
    pool.bind(1, 8, row_tokens=24, prefix=(6, blocks))
    assert pool.prefix_cow_copies == 1
    assert pool.shared_pages(1) == 1             # only the full block
    assert int(pool.page_table[1, 0]) == blocks[0]
    assert int(pool.page_table[1, 1]) != blocks[1]
    check_invariants(pool)


def test_bind_rollback_on_exhaustion_leaks_nothing(cfg):
    """A bind that passes the shared-pin stage but cannot allocate its
    fresh pages must roll the pins back (no refcount drift)."""
    pool = make_pool(cfg, num_blocks=9)          # 8 usable blocks + null
    prompt = np.arange(0, 20, dtype=np.int32)
    pool.bind(0, 16, row_tokens=16)              # 4 blocks
    pool.publish_prefix(0, prompt, 16)           # entries at 8 and 16
    n, blks = pool.match_prefix(prompt, 0)
    assert (n, len(blks)) == (16, 4)
    pool.bind(1, 20, row_tokens=20, prefix=(n, blks))   # 4 shared + 1 fresh
    pool.bind(2, 12, row_tokens=12)              # drain the free list
    assert pool.blocks.free_in(0) == 0
    assert pool.evictable_in(0) == 0             # entries shared with rows
    with pytest.raises(RuntimeError, match="bind without can_admit"):
        pool.bind(3, 20, row_tokens=20, prefix=(n, blks))
    assert pool._row_blocks[3] == []
    assert all(int(b) == NULL_BLOCK for b in pool.page_table[3])
    assert all(pool.blocks.refcount(b) > 0 for b in blks)  # pins rolled back
    check_invariants(pool)
