"""Serving observability: tracer + Perfetto export, streaming gate
calibration (ECE/reliability), trace schema validation, and the
traced-vs-untraced A/B (tracing must not change token streams or host
sync counts)."""
import json
import pathlib
import sys

import numpy as np
import pytest

from repro.serving.metrics import percentile
from repro.serving.observability import (ENGINE_PID, REQUEST_PID_BASE,
                                         GateCalibration, ReliabilityBins,
                                         Tracer, length_bucket)

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "scripts"))

import check_trace  # noqa: E402


# ---------------------------------------------------------------------------
# length_bucket boundaries / percentile edge cases (satellite fixes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,label", [
    (1, "1"), (2, "2"), (3, "3-4"), (4, "3-4"),
    (5, "5-8"), (8, "5-8"), (9, "9-16"), (16, "9-16"), (17, "17-32"),
    (64, "33-64"), (65, "65-128"),
])
def test_length_bucket_boundaries(n, label):
    assert length_bucket(n) == label


def test_length_bucket_is_reexported_by_metrics():
    # docs/tests historically import it from metrics; the canonical
    # definition moved to observability — both must be the same object
    from repro.serving import metrics
    assert metrics.length_bucket is length_bucket


def test_percentile_empty_is_nan():
    assert np.isnan(percentile([], 50))
    assert percentile([3.0], 95) == 3.0


# ---------------------------------------------------------------------------
# streaming reliability bins / ECE
# ---------------------------------------------------------------------------


def closed_form_ece(confs, corrects, bins):
    """Batch ECE with the same binning as ReliabilityBins
    (bin = min(int(c*bins), bins-1), last bin closed at 1.0)."""
    confs = np.asarray(confs, np.float64)
    corrects = np.asarray(corrects, np.float64)
    idx = np.minimum((confs * bins).astype(int), bins - 1)
    err = 0.0
    for b in range(bins):
        m = idx == b
        if m.sum() == 0:
            continue
        err += (m.sum() / len(confs)) * abs(confs[m].mean()
                                            - corrects[m].mean())
    return err


def test_streaming_ece_matches_closed_form():
    rng = np.random.default_rng(7)
    confs = rng.random(500)
    corrects = rng.random(500) < confs          # roughly calibrated
    rb = ReliabilityBins(bins=10)
    for c, k in zip(confs, corrects):
        rb.record(float(c), bool(k))
    assert rb.total == 500
    assert rb.ece() == pytest.approx(
        closed_form_ece(confs, corrects, 10), abs=1e-12)


def test_reliability_bins_edges_and_empty():
    rb = ReliabilityBins(bins=4)
    assert np.isnan(rb.ece())                   # no samples yet
    rb.record(0.0, True)                        # first bin
    rb.record(1.0, True)                        # conf=1.0 -> last bin
    rb.record(0.25, False)                      # exact edge -> bin 1
    assert rb.count.tolist() == [1, 1, 0, 1]
    d = rb.diagram()
    assert d[0]["n"] == 1 and d[0]["acc"] == 1.0
    assert d[3]["n"] == 1 and d[3]["conf"] == 1.0
    assert np.isnan(d[2]["conf"])               # empty bin stays NaN


def test_perfectly_calibrated_stream_has_zero_ece():
    rb = ReliabilityBins(bins=5)
    # every sample sits at a bin center with matching realized accuracy
    for center, acc in ((0.1, 0.1), (0.5, 0.5), (0.9, 0.9)):
        for i in range(10):
            rb.record(center, i < round(acc * 10))
    assert rb.ece() == pytest.approx(0.0, abs=1e-12)


def test_gate_calibration_streams_and_summary():
    cal = GateCalibration(n_gates=2, bins=10)
    cal.record_gate(0, 0.05, True)
    cal.record_gate(0, 0.95, False)
    cal.record_gate(1, 0.55, True)
    cal.record_outcome(0, 0.05, agree=True, prompt_len=7)
    cal.record_outcome(0, 0.15, agree=False, prompt_len=20)
    assert cal.conf_hist[0].tolist()[0] == 1
    assert cal.conf_hist[0].tolist()[9] == 1
    assert cal.esc_hist[0].sum() == 1           # only the low-conf escalated
    assert cal.agreement_rate(0) == 0.5
    assert np.isnan(cal.agreement_rate(1))      # no outcomes at gate 1
    s = cal.summary()
    assert [g["gate"] for g in s] == [0, 1]
    assert s[0]["seen"] == 2 and s[0]["outcomes"] == 2
    assert set(s[0]["ece_by_prompt_bucket"]) == {"5-8", "17-32"}
    assert len(s[0]["reliability"]) == 10
    json.dumps(s, default=float)                # BENCH-serializable


# ---------------------------------------------------------------------------
# tracer: ring buffer, event structure, export schema
# ---------------------------------------------------------------------------


def test_tracer_ring_buffer_drops_oldest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.counter("c", i)
    evs = tr.events()
    assert len(evs) == 4 and tr.dropped == 6
    assert [e["args"]["value"] for e in evs] == [6.0, 7.0, 8.0, 9.0]
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_tracer_request_lifecycle_pairs_and_export(tmp_path):
    tr = Tracer()
    tr.name_process(ENGINE_PID, "engine")
    tr.name_track(ENGINE_PID, 0, "tier0")
    tr.request_transition(7, "QUEUED", 0, prompt_tokens=12)
    tr.request_transition(7, "PREFILL", 0, shard=1)
    with tr.span("admit", tid=0, tick=3):
        pass
    tr.phase("plan", 0, tr.now_us(), width=4)
    tr.instant("gate", 0, conf=0.25)
    tr.request_done(7, 0)
    path = tmp_path / "t.json"
    n = tr.export(str(path))
    trace = json.loads(path.read_text())
    assert len(trace["traceEvents"]) == n
    assert trace["otherData"]["dropped_events"] == 0
    # schema-valid per the CI checker
    assert check_trace.validate_trace(trace) == []
    by_ph = {}
    for e in trace["traceEvents"]:
        by_ph.setdefault(e["ph"], []).append(e)
    # QUEUED and PREFILL each open ("b") and close ("e"), keyed by rid
    assert [e["name"] for e in by_ph["b"]] == ["QUEUED", "PREFILL"]
    assert all(e["id"] == 7 and e["cat"] == "request" for e in by_ph["b"])
    assert len(by_ph["e"]) == 2
    assert by_ph["b"][1]["pid"] == REQUEST_PID_BASE
    assert by_ph["b"][1]["tid"] == 1            # shard -> tid
    assert {e["name"] for e in by_ph["i"]} == {"gate", "DONE"}
    assert {e["name"] for e in by_ph["M"]} >= {"process_name",
                                               "thread_name"}


def test_check_trace_rejects_malformed_traces():
    ok = {"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "dur": 5, "pid": 0, "tid": 0},
        {"name": "b", "ph": "X", "ts": 1, "dur": 2, "pid": 0, "tid": 0},
    ]}
    assert check_trace.validate_trace(ok) == []
    cases = {
        "not an object": [1, 2],
        "missing traceEvents": {"foo": []},
        "negative dur": {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "dur": -1,
             "pid": 0, "tid": 0}]},
        "non-monotonic X": {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 5, "dur": 1,
             "pid": 0, "tid": 0},
            {"name": "b", "ph": "X", "ts": 2, "dur": 1,
             "pid": 0, "tid": 0}]},
        "half-overlap": {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "dur": 4,
             "pid": 0, "tid": 0},
            {"name": "b", "ph": "X", "ts": 2, "dur": 9,
             "pid": 0, "tid": 0}]},
        "dangling b": {"traceEvents": [
            {"name": "S", "ph": "b", "cat": "request", "id": 1,
             "ts": 0, "pid": 0, "tid": 0}]},
        "e without b": {"traceEvents": [
            {"name": "S", "ph": "e", "cat": "request", "id": 1,
             "ts": 0, "pid": 0, "tid": 0}]},
        "counter without numeric value": {"traceEvents": [
            {"name": "c", "ph": "C", "ts": 0, "pid": 0, "tid": 0,
             "args": {"value": "high"}}]},
        "missing ts": {"traceEvents": [
            {"name": "a", "ph": "i", "pid": 0, "tid": 0}]},
    }
    for label, trace in cases.items():
        assert check_trace.validate_trace(trace), label


# ---------------------------------------------------------------------------
# engine integration: traced run == untraced run, spans present,
# escalation-outcome calibration, tick durations, snapshots
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfg():
    from repro.configs import get_config
    return get_config("gemma3-1b", "smoke")


@pytest.fixture(scope="module")
def params(cfg):
    import jax
    import jax.numpy as jnp
    from repro.models import init_params
    return init_params(cfg, jax.random.PRNGKey(0), jnp.float32)


def _engine(cfg, params, tracer=None, deltas=(0.5,)):
    """Two tiers sharing params: escalated streams agree exactly, so
    the escalation-outcome proxy must report agreement 1.0."""
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock
    return CascadeEngine(
        [TierSpec("fast", cfg, params), TierSpec("exp", cfg, params)],
        slots=3, prompt_len=16, gen_len=4, deltas=list(deltas),
        kv_block_size=4, prefill_chunk=5, clock=VirtualClock(),
        tracer=tracer)


def _submit_all(eng, cfg, n=6):
    rng = np.random.default_rng(0)
    for i in range(n):
        p = rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(1, 17))).astype(np.int32)
        eng.submit(p, arrival_time=float(i // 2))


@pytest.fixture(scope="module")
def traced_run(cfg, params, tmp_path_factory):
    tr = Tracer()
    eng = _engine(cfg, params, tracer=tr)
    _submit_all(eng, cfg)
    snaps = []
    summary = eng.run(metrics_interval=3.0, on_snapshot=snaps.append)
    path = tmp_path_factory.mktemp("trace") / "trace.json"
    tr.export(str(path))
    return eng, summary, tr, snaps, path


def test_traced_run_matches_untraced(cfg, params, traced_run):
    eng_t, summary_t, _, _, _ = traced_run
    eng = _engine(cfg, params, tracer=None)
    _submit_all(eng, cfg)
    summary = eng.run()
    # tracing is observational: identical token streams, launches, and
    # (the big one) host sync counts
    assert [r.tokens for r in eng.requests] \
        == [r.tokens for r in eng_t.requests]
    assert summary["launches"] == summary_t["launches"]
    assert summary["host_syncs"] == summary_t["host_syncs"]
    assert summary["host_syncs_per_tick"] == summary_t["host_syncs_per_tick"]
    assert summary["steps"] == summary_t["steps"]


def test_traced_run_emits_schema_valid_spans(traced_run):
    eng, summary, tr, _, path = traced_run
    trace = json.loads(path.read_text())
    assert check_trace.validate_trace(trace) == []
    evs = trace["traceEvents"]
    phases = {e["name"] for e in evs if e["ph"] == "X"}
    assert {"tick", "admit", "plan", "launch",
            "device_get", "finish"} <= phases
    states = {e["name"] for e in evs if e["ph"] == "b"}
    assert {"QUEUED", "PREFILL", "DECODE", "ESCALATED"} <= states
    dones = [e for e in evs if e["ph"] == "i" and e["name"] == "DONE"]
    assert len(dones) == summary["completed"]
    # every tick span exists once per engine step
    ticks = [e for e in evs if e["ph"] == "X" and e["name"] == "tick"]
    assert len(ticks) == summary["steps"]
    # counter tracks sample queue depth / live rows
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert any(n.startswith("queue depth/") for n in counters)
    assert any(n.startswith("live rows/") for n in counters)


def test_escalation_outcome_calibration(traced_run):
    _, summary, _, _, _ = traced_run
    cal = summary["gate_calibration"]
    assert len(cal) == 1
    g = cal[0]
    # both tiers share params -> escalated token streams always agree
    assert g["outcomes"] > 0
    assert g["agreement_rate"] == 1.0
    # confidences are tiny (random params over a big vocab) and realized
    # "accuracy" is 1.0, so the proxy-ECE sits near 1 - mean_conf
    assert 0.9 < g["ece"] <= 1.0
    assert sum(g["conf_hist"]) == g["seen"] > 0
    assert g["ece_by_prompt_bucket"]            # bucketed slice populated


def test_no_escalation_means_no_outcomes(cfg, params):
    eng = _engine(cfg, params, deltas=(0.0,))   # conf > 0 -> never escalate
    _submit_all(eng, cfg, n=3)
    summary = eng.run()
    g = summary["gate_calibration"][0]
    assert g["outcomes"] == 0
    assert np.isnan(g["agreement_rate"]) and np.isnan(g["ece"])
    assert g["seen"] > 0                        # decisions still streamed


def test_tick_durations_under_virtual_clock(traced_run):
    eng, summary, _, _, _ = traced_run
    # VirtualClock advances exactly 1.0 per engine step
    assert summary["tick_duration_p50"] == 1.0
    assert summary["tick_duration_max"] == 1.0
    assert summary["tick_duration_hist"] == {"1e0": summary["steps"] - 1}
    assert len(eng.metrics.tick_durations) == summary["steps"] - 1


def test_metrics_interval_snapshots(traced_run):
    _, summary, _, snaps, _ = traced_run
    assert snaps, "run(metrics_interval=...) emitted no snapshots"
    assert all(s["t"] <= summary["steps"] + 1 for s in snaps)
    ts = [s["t"] for s in snaps]
    assert ts == sorted(ts)
    last = snaps[-1]
    assert {"completed", "escalation_rates", "gate_ece",
            "gate_agreement", "tick_duration_p50"} <= set(last)
