"""Unit tests for the paper's loss terms (Eqs 3-6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core import confidence as conf_lib


def _logits_for(labels, correct_mask, k, key, margin=5.0):
    """Build logits whose argmax == label exactly where correct_mask."""
    n = labels.shape[0]
    base = jax.random.normal(key, (n, k))
    # kill accidental argmax==label then add margin where correct
    base = base.at[jnp.arange(n), labels].set(base.min(-1) - 1.0)
    boost = jnp.where(correct_mask, margin + base.max(-1) - base[jnp.arange(n), labels], 0.0)
    return base.at[jnp.arange(n), labels].add(boost)


def test_cascade_loss_matches_equation3():
    key = jax.random.PRNGKey(0)
    n, k = 64, 10
    labels = jax.random.randint(key, (n,), 0, k)
    k1, k2 = jax.random.split(key)
    fast_ok = jax.random.bernoulli(k1, 0.6, (n,))
    exp_ok = jax.random.bernoulli(k2, 0.8, (n,))
    fl = _logits_for(labels, fast_ok, k, k1)
    el = _logits_for(labels, exp_ok, k, k2)
    c = 0.37
    got = losses.cascade_loss(fl, el, labels, cost_c=c)

    conf = jnp.max(jax.nn.softmax(fl, -1), -1)
    manual = jnp.mean(conf * (1 - fast_ok) + (1 - conf) * ((1 - exp_ok) + c))
    np.testing.assert_allclose(got, manual, rtol=1e-6)


def test_cascade_loss_gradient_direction():
    """dL/dconf = 1[fast wrong] - 1[exp wrong] - C: pushing conf down only
    when the expensive model would fix the error (+C tilt)."""
    key = jax.random.PRNGKey(1)
    n, k = 128, 5
    labels = jax.random.randint(key, (n,), 0, k)
    fast_ok = jnp.arange(n) % 2 == 0
    exp_ok = jnp.arange(n) % 4 < 2          # half of fast-wrong fixed by exp
    fl = _logits_for(labels, fast_ok, k, key)
    el = _logits_for(labels, exp_ok, k, key)

    def conf_of(fl):
        return losses.cascade_loss(fl, el, labels, cost_c=0.0)

    g = jax.grad(lambda f: conf_of(f))(fl)
    # where fast wrong & exp right: increasing max-prob raises the loss
    conf_grad = jnp.sum(g * jax.grad(lambda f: jnp.sum(conf_lib.max_prob(f)))(fl))
    assert jnp.isfinite(conf_grad)


def test_ltc_loss_reduces_to_org_when_w0():
    key = jax.random.PRNGKey(2)
    labels = jax.random.randint(key, (32,), 0, 7)
    fl = jax.random.normal(key, (32, 7))
    el = jax.random.normal(key, (32, 7))
    l, m = losses.ltc_loss(fl, el, labels, w=0.0)
    np.testing.assert_allclose(l, losses.cross_entropy(fl, labels), rtol=1e-6)


def test_ltc_chain_matches_pairwise_sum():
    key = jax.random.PRNGKey(3)
    labels = jax.random.randint(key, (16,), 0, 4)
    chain = [jax.random.normal(jax.random.PRNGKey(i), (16, 4))
             for i in range(3)]
    total, _ = losses.ltc_chain_loss(chain, labels, w=0.7, cost_c=0.2)
    manual = losses.cross_entropy(chain[-1], labels)
    for m in range(2):
        manual += losses.cross_entropy(chain[m], labels)
        manual += 0.7 * losses.cascade_loss(chain[m], chain[m + 1], labels, 0.2)
    np.testing.assert_allclose(total, manual, rtol=1e-6)


def test_cross_entropy_masking():
    key = jax.random.PRNGKey(4)
    logits = jax.random.normal(key, (4, 8, 11))
    labels = jax.random.randint(key, (4, 8), 0, 11)
    mask = jnp.zeros((4, 8)).at[:, :4].set(1.0)
    l_masked = losses.cross_entropy(logits, labels, mask)
    l_manual = losses.cross_entropy(logits[:, :4], labels[:, :4])
    np.testing.assert_allclose(l_masked, l_manual, rtol=1e-6)


def test_indicator_stop_gradient():
    """Correctness indicators must not leak gradient."""
    key = jax.random.PRNGKey(5)
    labels = jax.random.randint(key, (8,), 0, 3)
    el = jax.random.normal(key, (8, 3))

    def f(fl):
        return jnp.sum(losses.correct(fl, labels))

    g = jax.grad(f)(jax.random.normal(key, (8, 3)))
    np.testing.assert_array_equal(g, jnp.zeros_like(g))
