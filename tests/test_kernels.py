"""Per-kernel interpret-mode validation: shape/dtype sweeps vs the pure-jnp
oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp_compat import given, settings, st  # hypothesis or skip-stub

from repro.kernels import ref
from repro.kernels.confidence_gate import confidence_gate
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.router_gate import router_gate
from repro.kernels.rwkv6_scan import rwkv6_scan

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# confidence_gate
# --------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 100), (8, 1024), (5, 4097), (1, 31),
                                   (2, 3, 700)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_confidence_gate_sweep(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 4).astype(dtype)
    out = confidence_gate(x, interpret=True)
    want = ref.confidence_gate_ref(x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(out["conf"], want["conf"], rtol=tol, atol=tol)
    np.testing.assert_allclose(out["entropy"], want["entropy"], rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(out["logz"], want["logz"], rtol=tol, atol=tol)
    np.testing.assert_array_equal(out["argmax"], want["argmax"])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 13), st.integers(2, 3000), st.integers(0, 2 ** 31 - 1))
def test_confidence_gate_property(rows, vocab, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, vocab)) * 3
    out = confidence_gate(x, interpret=True)
    # invariants: conf in (0,1]; entropy in [0, log V]; argmax in range
    assert np.all(out["conf"] > 0) and np.all(out["conf"] <= 1 + 1e-6)
    assert np.all(out["entropy"] >= -1e-5)
    assert np.all(out["entropy"] <= np.log(vocab) + 1e-4)
    assert np.all(out["argmax"] >= 0) and np.all(out["argmax"] < vocab)


# --------------------------------------------------------------------------
# router_gate
# --------------------------------------------------------------------------


@pytest.mark.parametrize("shape,k", [((13, 40), 8), ((32, 384), 8),
                                     ((4, 16, 64), 6), ((7, 100), 2),
                                     ((8, 16), 1)])
def test_router_gate_sweep(shape, k):
    x = jax.random.normal(KEY, shape) * 2
    g, i = router_gate(x, k, interpret=True)
    gr, ir = ref.router_gate_ref(x, k)
    np.testing.assert_array_equal(i, ir)
    np.testing.assert_allclose(g, gr, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 100), st.integers(1, 8))
def test_router_gate_property(seed, e, k):
    k = min(k, e)
    x = jax.random.normal(jax.random.PRNGKey(seed), (6, e)) * 3
    g, i = router_gate(x, k, interpret=True)
    # gates renormalized to 1; indices unique per row and in range
    np.testing.assert_allclose(np.sum(np.asarray(g), -1), 1.0, rtol=1e-5)
    idx = np.asarray(i)
    assert idx.min() >= 0 and idx.max() < e
    for row in idx:
        assert len(set(row.tolist())) == k


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,KV,S,T,d,causal,window", [
    (1, 4, 2, 128, 128, 64, True, None),
    (2, 2, 2, 100, 100, 32, True, None),
    (1, 4, 1, 256, 256, 64, True, 100),     # GQA kv=1 + sliding window
    (1, 2, 2, 64, 192, 64, False, None),    # cross-length, non-causal
    (1, 8, 2, 130, 130, 128, True, None),   # ragged tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, S, T, d, causal, window, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, H, S, d)).astype(dtype)
    k = jax.random.normal(k2, (B, KV, T, d)).astype(dtype)
    v = jax.random.normal(k3, (B, KV, T, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------
# rwkv6_scan
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,T,hd", [(1, 2, 64, 32), (2, 3, 200, 64),
                                      (1, 1, 128, 64), (1, 2, 301, 64)])
def test_rwkv6_scan_sweep(B, H, T, hd):
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (B, H, T, hd)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, H, T, hd)) * 0.5))
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    out = rwkv6_scan(r, k, v, w, u, interpret=True)
    want = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


def test_rwkv6_state_continuity():
    """Splitting the sequence across chunk boundaries must not change y."""
    ks = jax.random.split(KEY, 5)
    B, H, T, hd = 1, 1, 256, 32
    r, k, v = (jax.random.normal(ks[i], (B, H, T, hd)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, H, T, hd)) * 0.3))
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    full = rwkv6_scan(r, k, v, w, u, interpret=True)
    want = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(full, want, rtol=3e-4, atol=3e-4)


# --------------------------------------------------------------------------
# mamba_scan
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,d,n", [(1, 64, 32, 8), (2, 150, 96, 16),
                                     (1, 128, 600, 16), (1, 257, 64, 16)])
def test_mamba_scan_sweep(B, T, d, n):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, T, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, d))) * 0.1
    Bt = jax.random.normal(ks[2], (B, T, n))
    Ct = jax.random.normal(ks[3], (B, T, n))
    A = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    out = mamba_scan(x, dt, Bt, Ct, A, interpret=True)
    want = ref.mamba_scan_ref(x, dt, Bt, Ct, A)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


def test_refs_match_model_blocks():
    """The kernel oracles and the model's jnp substrate agree (attention)."""
    from repro.models import blocks
    from repro.configs.base import Attn, ModelConfig

    B, S, D, H, KV, hd = 2, 32, 64, 4, 2, 16
    cfg = ModelConfig(name="t", family="dense", d_model=D, vocab_size=16,
                      num_heads=H, num_kv_heads=KV, head_dim=hd)
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    want = ref.flash_attention_ref(q, k, v, causal=True)
    # blocks' einsum path: q [B,S,KV,G,hd]; k,v [B,T,KV,hd]
    qg = q.reshape(B, KV, H // KV, S, hd).transpose(0, 3, 1, 2, 4)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    causal = (jnp.arange(S)[:, None] >= jnp.arange(S)[None, :])
    mask = causal[None, None, None]                      # [1,1,1,S,S]
    got = blocks._gqa_scores_to_out(qg, kk, vv, mask)
    got = got.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
