"""Bit-identity parity for refcounted KV prefix caching.

Shared KV blocks hold exactly the bytes a fresh prefill of the same
tokens would write (prefill is deterministic per tier), and greedy
decode reads KV values, never block ids — so turning the cache on may
change *where prompt KV comes from* and how many prefill tokens are
computed, but never a token.  Every test here serves a shared-prefix
workload twice, cache on vs off, under a deterministic VirtualClock and
a fixed δ (budget-δ calibrates from arrival order, which the cache is
allowed to change), and asserts identical per-request token streams and
tier routing:

  * uniform and mixed (lognormal) prompt lengths;
  * an over-subscribed arena where admission must LRU-evict index
    entries and the reserve discipline interleaves with pinned shared
    blocks;
  * a two-tier cascade where escalated requests re-prefill at the
    target tier — each tier owns its own pool and prefix index, so
    cross-tier block aliasing is structurally impossible (asserted);
  * an 8-simulated-device sharded engine (subprocess, the
    tests/test_sharded_serving.py pattern) with per-shard indices.

The uniform workload also pins the headline win: at a 5/6-shared
workload the cache must at least halve live prefill tokens.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serving import CascadeEngine, TierSpec
from repro.serving.engine import VirtualClock
from repro.serving.request import TERMINAL_STATES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def tiny_parts():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    p0 = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    p1 = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    return cfg, p0, p1


def _build(parts, tiers=1, **kw):
    cfg, p0, p1 = parts
    specs = [TierSpec("fast", cfg, p0)]
    if tiers == 2:
        specs.append(TierSpec("exp", cfg, p1))
        kw.setdefault("deltas", [0.5])
    kw.setdefault("slots", 2)
    kw.setdefault("prompt_len", 24)
    kw.setdefault("gen_len", 4)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("kv_block_size", 4)
    return CascadeEngine(specs, clock=VirtualClock(), **kw)


def _shared_prefix_prompts(cfg, n=8, plen=24, shared=20, seed=0):
    """n prompts agreeing on their first `shared` tokens (one base
    sequence) with unique tails — the system-prompt workload."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    out = []
    for i in range(n):
        p = base.copy()
        p[shared:] = rng.integers(0, cfg.vocab_size, plen - shared)
        out.append(p)
    return out


def _drain(eng, prompts, max_steps=800):
    for p in prompts:
        eng.submit(p, arrival_time=0.0)
    s = eng.run(max_steps=max_steps)
    assert all(r.state in TERMINAL_STATES for r in eng.requests)
    assert s["conservation"]["ok"], s["conservation"]
    return s


def _results(eng):
    return [(r.rid, tuple(r.tokens), r.tier) for r in eng.requests]


def _check_parity(off, on):
    assert len(off) == len(on)
    for a, b in zip(off, on):
        assert a[0] == b[0]
        assert a[1] == b[1], (a, b)     # bit-identical token streams
        assert a[2] == b[2], (a, b)     # identical tier routing


# -- configuration guard -----------------------------------------------------


def test_prefix_cache_requires_chunked_prefill(tiny_parts):
    with pytest.raises(ValueError, match="prefix caching requires"):
        _build(tiny_parts, prefix_cache=True, use_chunked_prefill=False)
    with pytest.raises(ValueError, match="prefix caching requires"):
        _build(tiny_parts, prefix_cache=True, use_paged_kv=False)


# -- single-tier parity ------------------------------------------------------


def test_parity_and_token_savings_uniform(tiny_parts):
    cfg = tiny_parts[0]
    prompts = _shared_prefix_prompts(cfg)
    off = _build(tiny_parts)
    s_off = _drain(off, prompts)
    on = _build(tiny_parts, prefix_cache=True)
    s_on = _drain(on, prompts)
    _check_parity(_results(off), _results(on))
    pc = s_on["prefix_cache"]
    assert pc["hits"] > 0 and pc["cached_tokens"] > 0
    assert s_off["prefix_cache"]["lookups"] == 0    # off engine never looks
    # the headline: cached chunks are never re-prefilled, so live prefill
    # tokens must at least halve on this 5/6-shared workload
    assert s_off["prefill_live_tokens"] \
        >= 2 * s_on["prefill_live_tokens"], \
        (s_off["prefill_live_tokens"], s_on["prefill_live_tokens"])
    stats = on.runtimes[0].pool.memory_stats()
    assert stats["kv_shared_high_water_blocks"] > 0
    assert stats["prefix_index_entries"] > 0


def test_parity_lognormal_lengths(tiny_parts):
    """Mixed prompt lengths off one shared base: short prompts match
    shorter boundaries (or none), long ones the deepest — every length
    still decodes the same tokens with the cache on."""
    cfg = tiny_parts[0]
    rng = np.random.default_rng(3)
    base = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    lens = np.clip(np.rint(rng.lognormal(np.log(12), 0.6, 8)),
                   2, 24).astype(int)
    prompts = []
    for i, L in enumerate(lens):
        p = base[:L].copy()
        cut = max(1, int(0.8 * L))
        p[cut:] = rng.integers(0, cfg.vocab_size, L - cut)
        prompts.append(p)
    off = _build(tiny_parts)
    _drain(off, prompts)
    on = _build(tiny_parts, prefix_cache=True)
    s_on = _drain(on, prompts)
    _check_parity(_results(off), _results(on))
    assert s_on["prefix_cache"]["lookups"] == len(prompts)


def test_parity_oversubscribed_arena(tiny_parts):
    """Over-subscribed arena: admission pins shared blocks, the LRU
    reclaim path evicts index entries under pressure, and the
    oldest-first reserve discipline interleaves with both — still
    bit-identical, still conserved."""
    cfg = tiny_parts[0]
    prompts = _shared_prefix_prompts(cfg, n=10, seed=5)
    # 2 rows * 7 pages (max_seq 28, bs 4) + null = 15 full; 14 blocks
    # over-subscribes just enough that decode growth LRU-evicts index
    # entries while later admissions still find survivors to hit
    kw = dict(slots=2, kv_blocks=14)
    off = _build(tiny_parts, **kw)
    _drain(off, prompts)
    on = _build(tiny_parts, prefix_cache=True, **kw)
    s_on = _drain(on, prompts)
    _check_parity(_results(off), _results(on))
    assert s_on["prefix_cache"]["hits"] > 0
    # the reclaim path genuinely fired: growth evicted LRU entries
    stats = on.runtimes[0].pool.memory_stats()
    assert stats["prefix_evictions"] > 0
    assert stats["kv_shared_high_water_blocks"] > 0


def test_parity_with_preemption(tiny_parts):
    """Preemption storms against a warm cache: a preempted victim's
    release must not reclaim blocks the index (or other rows) still
    references, and its replay may legitimately hit the cache."""
    cfg = tiny_parts[0]
    prompts = _shared_prefix_prompts(cfg, n=10, seed=9)
    kw = dict(slots=4, kv_blocks=16, preemption_policy="youngest")
    off = _build(tiny_parts, **kw)
    s_off = _drain(off, prompts)
    on = _build(tiny_parts, prefix_cache=True, **kw)
    s_on = _drain(on, prompts)
    _check_parity(_results(off), _results(on))
    assert s_off["completed"] == s_on["completed"] == len(prompts)


# -- two-tier escalation -----------------------------------------------------


def test_two_tier_parity_and_no_cross_tier_alias(tiny_parts):
    """Escalated requests re-prefill at the target tier and may hit that
    tier's own index; block ids never cross tiers (each tier owns its
    pool, allocator, and index — asserted structurally)."""
    cfg = tiny_parts[0]
    prompts = _shared_prefix_prompts(cfg, n=8, seed=2)
    # probe pass: pick a fixed δ at the widest tier-0 confidence gap so
    # the gate genuinely splits traffic (smoke params cluster low)
    probe = _build(tiny_parts, tiers=2)
    _drain(probe, prompts)
    confs = sorted(r.seq_conf_by_tier[0] for r in probe.requests)
    gaps = [(confs[i + 1] - confs[i], i) for i in range(len(confs) - 1)]
    _, i = max(gaps)
    delta = 0.5 * (confs[i] + confs[i + 1])
    off = _build(tiny_parts, tiers=2, deltas=[delta])
    _drain(off, prompts)
    on = _build(tiny_parts, tiers=2, prefix_cache=True, deltas=[delta])
    s_on = _drain(on, prompts)
    _check_parity(_results(off), _results(on))
    tiers = {r[2] for r in _results(on)}
    assert tiers == {0, 1}, tiers       # δ=0.5 really splits traffic
    pc = s_on["prefix_cache"]
    assert pc["hits_by_tier"][0] > 0
    assert pc["hits_by_tier"][1] > 0    # escalations re-packed, re-hit
    # no cross-tier aliasing: every index entry's blocks live in its own
    # tier's allocator, and the pools/caches are distinct objects
    pools = [rt.pool for rt in on.runtimes]
    assert pools[0] is not pools[1]
    assert pools[0].cache is not pools[1].cache
    for pool in pools:
        for shard_idx in pool._index:
            for ent in shard_idx.values():
                assert all(b in pool.blocks._used for b in ent.blocks)


# -- sharded parity (subprocess, 8 simulated host devices) -------------------


def _run_child(code: str, timeout=540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_parity_prefix_cache():
    """Per-tier 4-device data meshes: each data shard keeps its own
    prefix index (blocks stay on the shard that decodes the row), and
    the sharded cache-on engine bit-matches both the sharded cache-off
    engine and the single-device cache-on engine."""
    out = _run_child("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock
    from repro.launch.mesh import make_tier_meshes

    assert jax.device_count() == 8, jax.device_count()
    fast = get_config("gemma3-1b", "smoke")
    exp = get_config("phi4-mini-3.8b", "smoke")
    fp = init_params(fast, jax.random.PRNGKey(0), jnp.float32)
    ep = init_params(exp, jax.random.PRNGKey(1), jnp.float32)
    vocab = min(fast.vocab_size, exp.vocab_size)

    def build(meshes, **kw):
        m = [None, None] if meshes is None else meshes
        eng = CascadeEngine(
            [TierSpec("fast", fast, fp, mesh=m[0]),
             TierSpec("exp", exp, ep, mesh=m[1])],
            deltas=[0.5], clock=VirtualClock(), slots=8,
            prompt_len=24, gen_len=4, prefill_chunk=8,
            kv_block_size=4, **kw)
        eng.warmup()
        return eng

    def drain(eng, prompts):
        for p in prompts:
            eng.submit(np.asarray(p, np.int32), arrival_time=0.0)
        s = eng.run(max_steps=3000)
        return s, [(r.rid, tuple(r.tokens), r.tier)
                   for r in eng.requests]

    rng = np.random.default_rng(7)
    base = rng.integers(0, vocab, 24).astype(np.int32)
    prompts = []
    for i in range(10):
        p = base.copy()
        p[20:] = rng.integers(0, vocab, 4)
        prompts.append(p)

    meshes = lambda: make_tier_meshes([(4, 1), (4, 1)])
    _, single_on = drain(build(None, prefix_cache=True), prompts)
    _, shard_off = drain(build(meshes()), prompts)
    s_on, shard_on = drain(build(meshes(), prefix_cache=True), prompts)
    assert shard_on == shard_off, "sharded cache on/off diverged"
    assert shard_on == single_on, "sharded vs single-device diverged"
    assert s_on["prefix_cache"]["hits"] > 0
    print("PARITY-OK")
    """)
    assert "PARITY-OK" in out
