"""Chunked paged prefill: kernel vs oracle, mixed-length serving
bit-exactness, token-budget admission, and the dense/uniform fallbacks."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.serving import CascadeEngine, CascadeScheduler, GateSpec, TierSpec
from repro.serving.engine import VirtualClock
from repro.serving.metrics import length_bucket
from repro.serving.request import Request, RequestState


# ---------------------------------------------------------------------------
# kernel vs jnp oracle
# ---------------------------------------------------------------------------


def _rand_pool(rng, B, C, KV, G, hd, N, bs, P, quant=False):
    q = jnp.asarray(rng.standard_normal((B, C, KV, G, hd)), jnp.float32)
    pt = jnp.asarray(rng.integers(1, N, (B, P)), jnp.int32)
    if quant:
        k = jnp.asarray(rng.integers(-127, 128, (N, bs, KV, hd)), jnp.int8)
        v = jnp.asarray(rng.integers(-127, 128, (N, bs, KV, hd)), jnp.int8)
        ks = jnp.asarray(rng.uniform(0.01, 0.05, (N, bs, KV)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.01, 0.05, (N, bs, KV)), jnp.float32)
        return q, k, v, pt, ks, vs
    k = jnp.asarray(rng.standard_normal((N, bs, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((N, bs, KV, hd)), jnp.float32)
    return q, k, v, pt, None, None


@pytest.mark.parametrize("window", [None, 6])
def test_prefill_kernel_matches_oracle(window):
    rng = np.random.default_rng(0)
    B, C, KV, G, hd = 3, 8, 2, 2, 16
    N, bs, P = 11, 4, 6
    q, k, v, pt, _, _ = _rand_pool(rng, B, C, KV, G, hd, N, bs, P)
    # chunk starts straddle block boundaries; one row is a stalled /
    # non-prefilling row (q_len 0) and must output exactly zero
    start = jnp.asarray([0, 5, 13], jnp.int32)
    qlen = jnp.asarray([8, 3, 0], jnp.int32)
    got = kernel_ops.paged_prefill_attention(
        q, k, v, pt, start, qlen, window=window, interpret=True)
    want = ref.paged_prefill_attention_ref(
        q, k, v, pt, start, qlen, window=window)
    for b in range(B):
        n = int(qlen[b])
        np.testing.assert_allclose(np.asarray(got)[b, :n],
                                   np.asarray(want)[b, :n],
                                   rtol=2e-5, atol=2e-6)
    np.testing.assert_array_equal(np.asarray(got)[2], 0.0)


def test_prefill_kernel_int8_dequant_matches_oracle():
    rng = np.random.default_rng(1)
    B, C, KV, G, hd = 2, 4, 1, 3, 8
    N, bs, P = 9, 4, 4
    q, k, v, pt, ks, vs = _rand_pool(rng, B, C, KV, G, hd, N, bs, P,
                                     quant=True)
    start = jnp.asarray([2, 9], jnp.int32)
    qlen = jnp.asarray([4, 2], jnp.int32)
    got = kernel_ops.paged_prefill_attention(
        q, k, v, pt, start, qlen, k_scale=ks, v_scale=vs, interpret=True)
    want = ref.paged_prefill_attention_ref(
        q, k, v, pt, start, qlen, k_scale=ks, v_scale=vs)
    for b in range(B):
        n = int(qlen[b])
        np.testing.assert_allclose(np.asarray(got)[b, :n],
                                   np.asarray(want)[b, :n],
                                   rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# scheduler: token-budget admission
# ---------------------------------------------------------------------------


def _req(rid, plen, arrival=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32), gen_len=2,
                   arrival_time=arrival)


def test_scheduler_token_budget_caps_admitted_prompt_tokens():
    sched = CascadeScheduler([8], [])
    for i, plen in enumerate([10, 10, 10, 10]):
        sched.submit(_req(i, plen))
    got, _ = sched.admit(0, now=0.0, token_budget=25)
    assert [r.rid for r in got] == [0, 1]       # 10+10 fits, +10 would not
    got, _ = sched.admit(0, now=0.0, token_budget=25)
    assert [r.rid for r in got] == [2, 3]


def test_scheduler_token_budget_never_starves_long_prompts():
    sched = CascadeScheduler([4], [])
    sched.submit(_req(0, 100))                  # longer than the budget
    sched.submit(_req(1, 4))
    got, _ = sched.admit(0, now=0.0, token_budget=16)
    assert [r.rid for r in got] == [0]          # first always admitted
    got, _ = sched.admit(0, now=0.0, token_budget=16)
    assert [r.rid for r in got] == [1]


def test_scheduler_peek_respects_arrivals_and_slots():
    sched = CascadeScheduler([1], [])
    sched.submit(_req(0, 4, arrival=5.0))
    assert sched.peek(0, now=1.0) is None       # not arrived
    assert sched.peek(0, now=5.0).rid == 0
    sched.admit(0, now=5.0)
    sched.submit(_req(1, 4, arrival=5.0))
    assert sched.peek(0, now=6.0) is None       # no free slot


def test_engine_token_budget_paces_admission():
    """With a one-chunk token budget, a burst of arrivals is admitted at
    most budget prompt-tokens per tick even though rows are free."""
    cfg, fast_p, exp_p = _tiny_parts()
    eng = _mk(cfg, fast_p, exp_p, slots=6, prompt_len=8, prefill_chunk=8,
              prefill_token_budget=8)
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32))
    eng.step(0.0)
    assert len(eng.runtimes[0].occupied()) == 1     # 8 of 8 budget tokens
    eng.clock.step_done()
    eng.step(1.0)
    assert len(eng.runtimes[0].occupied()) == 2
    eng.run(max_steps=200)
    assert all(r.state is RequestState.DONE for r in eng.requests)


# ---------------------------------------------------------------------------
# engine: mixed-length bit-exactness
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_parts():
    return _tiny_parts()


def _tiny_parts():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    fast_p = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    exp_p = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    return cfg, fast_p, exp_p


def _mk(cfg, fast_p, exp_p, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("prompt_len", 16)
    kw.setdefault("gen_len", 4)
    kw.setdefault("deltas", [0.5])
    kw.setdefault("kv_block_size", 4)
    kw.setdefault("clock", VirtualClock())
    return CascadeEngine([TierSpec("fast", cfg, fast_p),
                          TierSpec("exp", cfg, exp_p)], **kw)


def test_mixed_lengths_match_per_request_uniform_runs(tiny_parts):
    """Acceptance: a mixed-length batch — lengths straddling the chunk
    boundary, incl. 1 and max_prompt_len — produces token streams
    bit-identical to per-request runs through the uniform one-shot
    prefill path (the chunked path's oracle)."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(0)
    chunk = 5
    lens = [1, 3, chunk, chunk + 1, 2 * chunk, 16]   # 16 == max_prompt_len
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]

    eng = _mk(cfg, fast_p, exp_p, prefill_chunk=chunk)
    assert eng.chunked_prefill
    for i, p in enumerate(prompts):
        eng.submit(p, arrival_time=float(i % 3))
    eng.run(max_steps=500)
    assert all(r.state is RequestState.DONE for r in eng.requests)

    for p, r in zip(prompts, eng.requests):
        uni = _mk(cfg, fast_p, exp_p, prompt_len=len(p),
                  use_chunked_prefill=False)
        uni.submit(p, arrival_time=0.0)
        uni.run()
        u = uni.requests[0]
        assert r.tokens == u.tokens
        assert r.tier == u.tier
        np.testing.assert_allclose(r.token_conf, u.token_conf, rtol=1e-5)


def test_chunked_uniform_matches_dense_fallback(tiny_parts):
    """Regression: with uniform lengths, the chunked engine, the paged
    one-shot engine, and the PR 1 dense arena all emit identical
    streams — the fallbacks still match seed behaviour."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, cfg.vocab_size, (5, 8)).astype(np.int32)

    outs = []
    for kw in ({"prefill_chunk": 3},
               {"use_chunked_prefill": False},
               {"use_chunked_prefill": False, "use_paged_kv": False}):
        eng = _mk(cfg, fast_p, exp_p, prompt_len=8, **kw)
        for i, p in enumerate(prompts):
            eng.submit(p, arrival_time=float(i % 2))
        eng.run()
        outs.append(eng.requests)
    for a, b, c in zip(*outs):
        assert a.tokens == b.tokens == c.tokens
        assert a.tier == b.tier == c.tier
        np.testing.assert_allclose(a.token_conf, b.token_conf, rtol=1e-5)


def test_mixed_lengths_with_oversubscribed_arena(tiny_parts):
    """Prefill chunks stall (not corrupt) when the block pool runs dry:
    an over-subscribed mixed-length run completes with streams identical
    to the fully-provisioned run."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(7)
    lens = [2, 16, 7, 11, 16, 4, 9, 1]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]

    def build(kv_blocks):
        return _mk(cfg, fast_p, exp_p, slots=4, prefill_chunk=4,
                   kv_blocks=kv_blocks)

    runs = []
    for kv_blocks in ([12, None], None):    # 11 usable blocks = 44 tokens
        eng = build(kv_blocks)
        for p in prompts:
            eng.submit(p, arrival_time=0.0)
        eng.run(max_steps=1000)
        assert all(r.state is RequestState.DONE for r in eng.requests)
        runs.append(eng.requests)
    for a, b in zip(*runs):
        assert a.tokens == b.tokens
        np.testing.assert_allclose(a.token_conf, b.token_conf, rtol=1e-5)


def test_chunked_prefill_rejected_for_recurrent_and_dense(tiny_parts):
    from repro.configs import get_config
    from repro.models import init_params
    cfg, fast_p, _ = tiny_parts
    with pytest.raises(ValueError, match="chunked prefill requires"):
        CascadeEngine([TierSpec("t", cfg, fast_p)], slots=2, prompt_len=8,
                      gen_len=2, deltas=[], use_paged_kv=False,
                      use_chunked_prefill=True)
    jcfg = get_config("jamba-v0.1-52b", "smoke")    # mamba: recurrent
    jp = init_params(jcfg, jax.random.PRNGKey(0), jnp.float32)
    with pytest.raises(ValueError, match="chunked prefill requires"):
        CascadeEngine([TierSpec("t", jcfg, jp)], slots=2, prompt_len=8,
                      gen_len=2, deltas=[], use_chunked_prefill=True)
    # auto mode falls back to the uniform path for recurrent models
    eng = CascadeEngine([TierSpec("t", jcfg, jp)], slots=2, prompt_len=8,
                        gen_len=2, deltas=[])
    assert not eng.chunked_prefill


def test_mixed_length_submit_validation(tiny_parts):
    cfg, fast_p, exp_p = tiny_parts
    eng = _mk(cfg, fast_p, exp_p, prompt_len=8)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(9, np.int32))       # beyond max_prompt_len
    with pytest.raises(ValueError):
        eng.submit(np.zeros(0, np.int32))       # empty
    uni = _mk(cfg, fast_p, exp_p, prompt_len=8, use_chunked_prefill=False)
    with pytest.raises(ValueError):
        uni.submit(np.zeros(5, np.int32))       # uniform path: exact only


def test_prefill_token_accounting(tiny_parts):
    """The padding-tax metric: live prompt tokens vs token slots the
    prefill batches processed.  The ragged flat layout (the default)
    packs only live tokens, so its ratio is exactly 1.  The padded
    mixed program pays capacity*chunk slots per chunked tick; unified
    admission charges first chunks only (3 + 4 = 7 fits the 8-token
    budget, so both requests enter at tick 0: three chunked ticks of
    capacity*chunk = 8 token slots).  The legacy split window charges
    full prompts (3 + 9 exceeds it, delaying the 9-token request to
    tick 1: four ticks of capacity*chunk = 8)."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 9)]

    def run(**kw):
        eng = _mk(cfg, fast_p, exp_p, slots=2, prompt_len=16,
                  prefill_chunk=4, deltas=[-1.0], **kw)  # nothing escalates
        for p in prompts:
            eng.submit(p)
        return eng.run(max_steps=200)

    s = run()
    assert s["prefill_live_tokens"] == 12
    assert s["prefill_processed_tokens"] == 12
    assert s["prefill_live_token_ratio"] == pytest.approx(1.0)
    assert s["prompt_len_max"] == 9
    s = run(use_ragged_step=False)
    assert s["prefill_live_tokens"] == 12
    assert s["prefill_processed_tokens"] == 24
    assert s["prefill_live_token_ratio"] == pytest.approx(12 / 24)
    s = run(use_unified_step=False)
    assert s["prefill_live_tokens"] == 12
    assert s["prefill_processed_tokens"] == 32
    assert s["prefill_live_token_ratio"] == pytest.approx(12 / 32)


def test_length_bucket_labels():
    assert length_bucket(1) == "1"
    assert length_bucket(2) == "2"
    assert length_bucket(3) == "3-4"
    assert length_bucket(4) == "3-4"
    assert length_bucket(5) == "5-8"
    assert length_bucket(900) == "513-1024"


# ---------------------------------------------------------------------------
# serve_async end-to-end (virtual clock)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dist", ["lognormal", "bimodal"])
def test_serve_async_mixed_length_end_to_end(dist, tiny_parts):
    """Acceptance: lognormal and bimodal length distributions run
    end-to-end through serve_async, and every request's stream is
    bit-identical to its per-request uniform-prefill run."""
    from repro.launch import serve_async
    cfg, fast_p, exp_p = tiny_parts

    args = serve_async.make_parser().parse_args([
        "--requests", "6", "--rate", "4", "--slots", "3",
        "--prompt-len", "16", "--gen-len", "3", "--prefill-chunk", "4",
        "--length-dist", dist, "--virtual-clock", "--delta", "0.5",
    ])
    engine, vocab = serve_async.build_engine(args, VirtualClock())
    prompts = [p for p in np.asarray(
        jax.random.randint(jax.random.PRNGKey(11), (6, 16), 0, vocab),
        np.int32)]
    lengths = serve_async.sample_lengths(dist, 6, 16, 1, seed=0)
    assert len(set(lengths.tolist())) > 1       # genuinely mixed
    arrivals = serve_async.poisson_arrivals(6, 4.0, 0)
    for p, n, t in zip(prompts, lengths, arrivals):
        engine.submit(p[:int(n)], arrival_time=float(t))
    s = engine.run(max_steps=1000)
    assert s["completed"] == 6
    assert s["ttft_p50_by_prompt_bucket"]

    for p, n, r in zip(prompts, lengths, engine.requests):
        uni = CascadeEngine(
            [TierSpec("fast", engine.tiers[0].cfg, engine.tiers[0].params),
             TierSpec("exp", engine.tiers[1].cfg, engine.tiers[1].params)],
            slots=3, prompt_len=int(n), gen_len=3, deltas=[0.5],
            clock=VirtualClock(), use_chunked_prefill=False)
        uni.submit(p[:int(n)], arrival_time=0.0)
        uni.run()
        assert r.tokens == uni.requests[0].tokens
        assert r.tier == uni.requests[0].tier


def test_serve_async_rejects_mixed_lengths_without_chunked_prefill():
    """The CLI guard must fire on any fallback to uniform prefill —
    explicit flags or the engine's auto-fallback — before serving."""
    from repro.launch import serve_async
    args = serve_async.make_parser().parse_args([
        "--requests", "2", "--slots", "2", "--prompt-len", "8",
        "--gen-len", "2", "--length-dist", "lognormal",
        "--no-chunked-prefill", "--virtual-clock"])
    with pytest.raises(ValueError, match="chunked paged prefill"):
        serve_async.run(args, VirtualClock())


def test_sample_lengths_distributions():
    from repro.launch import serve_async
    uni = serve_async.sample_lengths("uniform", 10, 64, 1, 0)
    assert (uni == 64).all()
    ln = serve_async.sample_lengths("lognormal", 200, 64, 1, 0)
    assert ln.min() >= 1 and ln.max() <= 64 and len(set(ln.tolist())) > 5
    bi = serve_async.sample_lengths("bimodal", 200, 64, 1, 0)
    assert bi.min() >= 1 and bi.max() <= 64
    # two modes: substantial mass both below and above the midpoint
    assert (bi < 24).mean() > 0.25 and (bi > 40).mean() > 0.25
    with pytest.raises(ValueError):
        serve_async.sample_lengths("zipf", 10, 64, 1, 0)
