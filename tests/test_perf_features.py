"""Beyond-paper perf features: gradient accumulation, int8 KV cache,
cache sharding options."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import steps as steps_lib
from repro.models import decode_step, forward, init_cache, init_params
from repro.models.cache import CP, cache_spec_leaf


def test_microbatched_train_step_matches_full_batch():
    """Grad accumulation must produce (nearly) the same update as the
    full-batch step for a linear-in-grads optimizer (SGD)."""
    cfg = dc.replace(get_config("gemma3-1b", "smoke"), optimizer="sgd")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, jnp.float32)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}

    s1, opt = steps_lib.make_train_step(cfg, lr=1e-2, microbatches=1)
    s4, _ = steps_lib.make_train_step(cfg, lr=1e-2, microbatches=4)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p4, _, m4 = jax.jit(s4)(params, opt.init(params), batch)

    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(diffs)) < 1e-4
    assert np.isfinite(float(m4["loss"]))


def test_int8_kv_cache_decode_close_to_fp32():
    cfg = get_config("phi4-mini-3.8b", "smoke")
    cfgq = dc.replace(cfg, kv_quant="int8")
    key = jax.random.PRNGKey(1)
    p = init_params(cfg, key, jnp.float32)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = forward(p, cfg, {"tokens": toks}, mode="train")

    _, part, _ = forward(p, cfgq, {"tokens": toks[:, :S - 1]}, mode="prefill")
    cache = init_cache(cfgq, B, S, jnp.float32)

    def put(full, piece):
        if full.shape == piece.shape:
            return piece.astype(full.dtype)
        return full.at[tuple(slice(0, d) for d in piece.shape)].set(
            piece.astype(full.dtype))

    cache = jax.tree.map(put, cache, part)
    # int8 leaves really are int8
    leaves = jax.tree.leaves(cache)
    assert any(l.dtype == jnp.int8 for l in leaves)
    pos = jnp.full((B, 1), S - 1, jnp.int32)
    dec, _ = decode_step(p, cfgq, toks[:, S - 1:S], cache, pos)
    scale = float(jnp.max(jnp.abs(full_logits[:, S - 1])))
    err = float(jnp.max(jnp.abs(dec[:, 0] - full_logits[:, S - 1])))
    assert err / scale < 0.05, f"int8 KV too lossy: rel {err/scale:.3f}"


def test_cache_spec_seq_over_model():
    """kv=8 heads cannot shard a 16-way model axis: seq_over_model moves
    the model axis onto the cache's sequence dim."""
    import os
    import subprocess
    import sys
    import textwrap
    repo = __file__.rsplit("/tests/", 1)[0]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{repo}/src"
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_test_mesh
        from repro.models.cache import CP, cache_spec_leaf
        mesh = make_test_mesh(8)   # data=2, model=4
        leaf = CP((16, 64, 2, 32), ("batch", "kv_seq", "kv_heads", None),
                  jnp.bfloat16)   # kv=2 < model=4 -> not shardable
        base = cache_spec_leaf(leaf, mesh, shard_seq=False)
        opt = cache_spec_leaf(leaf, mesh, shard_seq=False,
                              seq_over_model=True)
        assert base[1] is None, base
        assert opt[1] == "model", opt
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=180)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
