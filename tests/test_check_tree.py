"""Repo-hygiene guard (scripts/check_tree.py): committed build artifacts
must fail CI — the regression that let commit ca4bfbe ship three
``__pycache__/*.pyc`` files."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "scripts"))

from check_tree import tracked_artifacts  # noqa: E402


def test_artifact_patterns():
    files = [
        "src/repro/serving/engine.py",
        "scripts/check_tree.py",
        "docs/serving.md",
        ".gitignore",
    ]
    bad = [
        "scripts/__pycache__/check_docs.cpython-310.pyc",
        "src/repro/kernels/__pycache__/prefill_attention.cpython-310.pyc",
        "__pycache__/x.pyc",
        "a/b/mod.pyc",
        "pkg.egg-info/PKG-INFO",
        ".pytest_cache/v/cache/lastfailed",
        "tests/.hypothesis/examples/deadbeef",
    ]
    assert tracked_artifacts(files) == []
    assert tracked_artifacts(bad) == bad
    # prefix lookalikes are not artifacts
    assert tracked_artifacts(["docs/pycache_notes.md", "src/epyc.py"]) == []


def test_repo_tree_is_clean():
    """The guard itself passes on this repo (and .gitignore exists, so
    fresh *.pyc can't be committed by accident again)."""
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "check_tree.py")],
        capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 artifact(s)" in r.stdout
    assert os.path.exists(os.path.join(REPO, ".gitignore"))
