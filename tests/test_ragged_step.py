"""Ragged flat token-batch execution: the ragged-attention kernel vs its
jnp oracle over arbitrary per-row q_len in [0, C], the flat work-list
layout, and engine-level three-way parity — **bit-identical token
streams and escalation decisions** across the ragged flat executor, the
padded mixed executor, and the legacy split executor — over uniform,
lognormal, over-subscribed, preemption, and prefix-cache workloads,
single-device and on 8 simulated sharded devices.

Also asserts the compiled-program discipline the bucketed flat widths
exist for: warmup compiles every bucket, and no tick launches a width
outside the warmed set (zero mid-run recompiles across a mixed-length
run, where the legacy unified path paid a chunk-width AND a width-1
compile).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.kernels.ragged_attention import flat_work_layout
from repro.serving import CascadeEngine, CascadeScheduler, TierSpec  # noqa: F401
from repro.serving.engine import VirtualClock
from repro.serving.request import RequestState

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# kernel vs jnp oracle
# ---------------------------------------------------------------------------


def _flat_case(rng, B, C, KV, G, hd, P, bs, qlens, quant=False,
               window=None):
    """Build a flat-packed batch + pool and return (kernel, oracle)."""
    N = B * P + 1
    if quant:
        kp = jnp.asarray(rng.integers(-127, 128, (N, bs, KV, hd)), jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (N, bs, KV, hd)), jnp.int8)
        ks = jnp.asarray(rng.uniform(0.01, 0.05, (N, bs, KV)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.01, 0.05, (N, bs, KV)), jnp.float32)
    else:
        kp = jnp.asarray(rng.standard_normal((N, bs, KV, hd)), jnp.float32)
        vp = jnp.asarray(rng.standard_normal((N, bs, KV, hd)), jnp.float32)
        ks = vs = None
    pt = jnp.asarray(
        rng.permutation(np.arange(1, N))[:B * P].reshape(B, P), jnp.int32)
    q_len = np.asarray(qlens, np.int32)
    q_start = np.asarray([int(rng.integers(0, P * bs - C))
                          for _ in range(B)], np.int32)
    q_rows = rng.standard_normal((B, C, KV, G, hd)).astype(np.float32)
    total = int(q_len.sum())
    W = max(8, 1 << (max(total, 1) - 1).bit_length())
    flat = np.zeros((W, KV, G, hd), np.float32)
    o = 0
    for b in range(B):
        n = int(q_len[b])
        flat[o:o + n] = q_rows[b, :n]
        o += n
    args = (jnp.asarray(flat), kp, vp, pt, jnp.asarray(q_start),
            jnp.asarray(q_len))
    kw = dict(k_scale=ks, v_scale=vs, window=window)
    got = kernel_ops.ragged_attention(*args, interpret=True, **kw)
    want = ref.ragged_attention_ref(*args, **kw)
    return np.asarray(got), np.asarray(want), total


@pytest.mark.parametrize("qlens", [
    [3, 0, 16, 1, 1, 7, 0, 5],      # arbitrary mix incl. stalls
    [1] * 8,                        # decode-only tick
    [16] * 8,                       # full prefill tick
    [0] * 8,                        # all rows idle
    [16, 0, 0, 0, 0, 0, 0, 0],      # single live row
    [8, 8, 0, 0, 0, 0, 0, 0],       # total exactly a bucket boundary
])
def test_ragged_kernel_matches_oracle(qlens):
    """Rows with ANY q_len in [0, C] pack into one flat batch; outputs
    match the jnp oracle per token, and padding slots are exact zero."""
    rng = np.random.default_rng(0)
    got, want, total = _flat_case(rng, B=8, C=16, KV=2, G=2, hd=32,
                                  P=5, bs=16, qlens=qlens)
    np.testing.assert_allclose(got[:total], want[:total],
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_array_equal(got[total:], 0.0)


@pytest.mark.parametrize("quant,window", [(True, None), (False, 24),
                                          (True, 16)])
def test_ragged_kernel_int8_and_window(quant, window):
    rng = np.random.default_rng(7)
    qlens = rng.integers(0, 17, 8)
    got, want, total = _flat_case(rng, B=8, C=16, KV=2, G=2, hd=32,
                                  P=5, bs=16, qlens=qlens, quant=quant,
                                  window=window)
    np.testing.assert_allclose(got[:total], want[:total],
                               rtol=1e-4, atol=1e-5)


def test_ragged_kernel_odd_shapes():
    rng = np.random.default_rng(3)
    got, want, total = _flat_case(rng, B=3, C=5, KV=1, G=4, hd=16,
                                  P=3, bs=8, qlens=[5, 2, 4])
    np.testing.assert_allclose(got[:total], want[:total],
                               rtol=2e-5, atol=2e-6)


def test_flat_work_layout_covers_every_tile_once():
    """The static work list (length num_tiles + B) assigns every flat
    tile a contiguous span of owning rows in tile-major order, with
    first/last flags bracketing each tile's span — the invariant the
    kernel's accumulator init/finalize depends on."""
    rng = np.random.default_rng(5)
    for _ in range(50):
        B = int(rng.integers(1, 9))
        nt = int(rng.integers(1, 9))
        TQ = 16
        q_len = rng.integers(0, 33, B).astype(np.int32)
        while q_len.sum() > nt * TQ:
            q_len[rng.integers(B)] = 0
        wt, wr, wf, wl, rs = (np.asarray(a) for a in flat_work_layout(
            jnp.asarray(q_len), nt, TQ))
        assert wt.shape == (nt + B,)
        # tile-major sorted, every tile present at least once
        assert (np.diff(wt) >= 0).all()
        assert set(wt.tolist()) == set(range(nt))
        # per tile: exactly one first and one last flag
        for t in range(nt):
            span = np.where(wt == t)[0]
            assert wf[span].sum() == 1 and wf[span[0]] == 1
            assert wl[span].sum() == 1 and wl[span[-1]] == 1
        # every live row appears on each tile its token range intersects
        starts = np.concatenate([[0], np.cumsum(q_len)])[:B]
        for b in range(B):
            if q_len[b] == 0:
                continue
            lo, hi = starts[b], starts[b] + q_len[b]
            tiles = {t for t in range(nt)
                     if lo < (t + 1) * TQ and hi > t * TQ}
            got = {int(t) for t, r in zip(wt, wr) if r == b}
            assert got == tiles, (b, q_len, got, tiles)


# ---------------------------------------------------------------------------
# engine: ragged vs padded vs split three-way parity
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_parts():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    fast_p = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    exp_p = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    return cfg, fast_p, exp_p


def _mk(cfg, fast_p, exp_p, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("prompt_len", 16)
    kw.setdefault("gen_len", 4)
    kw.setdefault("deltas", [0.5])
    kw.setdefault("kv_block_size", 4)
    kw.setdefault("prefill_chunk", 5)
    kw.setdefault("clock", VirtualClock())
    return CascadeEngine([TierSpec("fast", cfg, fast_p),
                          TierSpec("exp", cfg, exp_p)], **kw)


def _drain(eng, prompts, arrivals=None):
    eng.warmup()
    for i, p in enumerate(prompts):
        t = 0.0 if arrivals is None else float(arrivals[i])
        eng.submit(p, arrival_time=t)
    eng.run(max_steps=1000)
    assert all(r.state is RequestState.DONE for r in eng.requests)
    return eng


def _check_streams(a_eng, b_eng):
    for a, b in zip(a_eng.requests, b_eng.requests):
        assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)
        assert a.tier == b.tier
        np.testing.assert_allclose(a.token_conf, b.token_conf, rtol=1e-5)


def _pick_delta(cfg, fast_p, exp_p, prompts, **kw):
    """Probe tier-0 confidences (no escalation) and return a δ in the
    widest gap, so the gate genuinely splits the batch."""
    probe = _drain(_mk(cfg, fast_p, exp_p, deltas=[0.0], **kw), prompts)
    confs = sorted(r.seq_conf_by_tier[0] for r in probe.requests)
    gaps = np.diff(confs)
    i = int(np.argmax(gaps))
    return float((confs[i] + confs[i + 1]) / 2)


def _three_way(cfg, fast_p, exp_p, prompts, arrivals=None, **kw):
    rag = _drain(_mk(cfg, fast_p, exp_p, **kw), prompts, arrivals)
    assert rag.ragged_step and all(rt.ragged for rt in rag.runtimes)
    pad = _drain(_mk(cfg, fast_p, exp_p, use_ragged_step=False, **kw),
                 prompts, arrivals)
    assert not pad.ragged_step and all(rt.unified and not rt.ragged
                                       for rt in pad.runtimes)
    spl = _drain(_mk(cfg, fast_p, exp_p, use_unified_step=False, **kw),
                 prompts, arrivals)
    _check_streams(rag, pad)
    _check_streams(rag, spl)
    return rag, pad, spl


def test_ragged_matches_padded_and_split_mixed_lengths(tiny_parts):
    """Acceptance: the flat executor's token streams bit-match the
    padded mixed executor AND the legacy split executor over mixed
    prompt lengths with staggered arrivals — and its realized
    wasted-slot ratio is strictly below the padded path's."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(0)
    lens = [1, 3, 5, 6, 10, 16]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    arrivals = [i % 3 for i in range(len(prompts))]
    delta = _pick_delta(cfg, fast_p, exp_p, prompts)
    rag, pad, _ = _three_way(cfg, fast_p, exp_p, prompts, arrivals,
                             deltas=[delta])
    assert {r.tier for r in rag.requests} == {0, 1}     # gate splits
    s_rag = rag.metrics.summary()
    s_pad = pad.metrics.summary()
    assert s_rag["wasted_slot_ratio"] < s_pad["wasted_slot_ratio"]
    # same launch discipline: one program per active tier per tick
    assert max(s_rag["launches_per_tick"]) <= 1.0 + 1e-9


def test_ragged_matches_split_oversubscribed_and_preemption(tiny_parts):
    """Stalls (block exhaustion) and evict-and-replay reorder work under
    the flat planner exactly as under the padded one: streams stay
    bit-identical across all three executors."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(7)
    lens = [2, 16, 7, 11, 16, 4, 9, 1]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    kw = dict(slots=4, prefill_chunk=4, kv_blocks=[12, None])
    _three_way(cfg, fast_p, exp_p, prompts, **kw)
    kw["preemption_policy"] = "youngest"
    _three_way(cfg, fast_p, exp_p, prompts, **kw)


def test_ragged_matches_padded_with_prefix_cache(tiny_parts):
    """Shared-prefix admissions start rows mid-prompt (q_start > 0 at
    the first uncached chunk): the flat scatter and per-row position
    map must reproduce the padded streams exactly."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(11)
    base = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    prompts = []
    for i in range(6):
        n = int(rng.integers(9, 17))
        p = base[:n].copy()
        p[8:] = rng.integers(0, cfg.vocab_size, n - 8)  # unique tails
        prompts.append(p)
    kw = dict(prefill_chunk=4, prefix_cache=True)
    rag, pad, _ = _three_way(cfg, fast_p, exp_p, prompts, **kw)
    assert sum(rag.metrics.prefix_hits_by_tier) > 0    # cache exercised


def test_ragged_gen_len_one(tiny_parts):
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 8, 16)]
    rag, _, _ = _three_way(cfg, fast_p, exp_p, prompts, gen_len=1)
    assert all(len(r.tokens) == 1 for r in rag.requests)


# ---------------------------------------------------------------------------
# bucketed flat widths: plan packing + zero mid-run recompiles
# ---------------------------------------------------------------------------


def test_step_plan_flat_packing(tiny_parts):
    """The plan's flat fields: live tokens concatenated in slot order at
    the smallest covering bucket, per-token positions, and per-row
    q_start = each row's first absolute position this tick."""
    cfg, fast_p, _ = tiny_parts
    eng = CascadeEngine([TierSpec("t", cfg, fast_p)], slots=4,
                        prompt_len=32, gen_len=4, prefill_chunk=8,
                        deltas=[], clock=VirtualClock())
    eng.warmup()
    eng.submit(np.arange(6, dtype=np.int32) % 5)        # finishes tick 1
    eng.step()
    eng.submit(np.arange(20, dtype=np.int32) % 7)       # 3 chunks
    eng.step()                              # admit long; short decodes
    rt = eng.runtimes[0]
    plan = eng._build_plan(rt)
    [dec] = plan.decode_rows
    [pre] = plan.prefill_rows
    live = int(plan.q_len.sum())
    assert live == rt.chunk + 1
    assert plan.flat_width == rt.bucket_width(live) >= live
    assert plan.flat_width in rt.flat_buckets
    # slot-order packing: row order by slot id, each row contiguous
    flat_tok, flat_pos, o = plan.flat_tokens[0], plan.flat_pos[0], 0
    for s in sorted((dec, pre)):
        n = int(plan.q_len[s])
        np.testing.assert_array_equal(flat_tok[o:o + n],
                                      plan.tokens[s, :n])
        np.testing.assert_array_equal(
            flat_pos[o:o + n], plan.q_start[s] + np.arange(n))
        o += n
    assert (flat_tok[o:] == 0).all()
    assert plan.q_start[dec] == rt.pos[dec]
    assert plan.q_start[pre] == rt.prefill_pos[pre]


def test_no_mid_run_recompiles_across_mixed_run(tiny_parts):
    """Warmup compiles every bucket width; a mixed-length run launches
    only warmed widths — the compile counter shows zero mid-run
    recompiles (the legacy warmup's chunk + width-1 double-compile is
    gone: padded tiers warm exactly their two widths, ragged tiers
    their buckets)."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (1, 4, 16, 9, 2, 13)]
    eng = _drain(_mk(cfg, fast_p, exp_p), prompts,
                 arrivals=[i % 4 for i in range(6)])
    for st in eng.compile_stats():
        assert st["backend"] == "ragged"
        assert st["mid_run_recompiles"] == [], st
        assert set(st["launched_widths"]) <= set(st["warmed_widths"])
    # the run really exercised more than one bucket width
    assert any(len(st["launched_widths"]) > 1
               for st in eng.compile_stats())


def test_flat_bucket_validation(tiny_parts):
    cfg, fast_p, _ = tiny_parts
    kw = dict(slots=2, prompt_len=16, gen_len=2, deltas=[],
              prefill_chunk=8)
    # largest bucket must cover slots * chunk
    with pytest.raises(ValueError, match="cover the"):
        CascadeEngine([TierSpec("t", cfg, fast_p)], flat_buckets=[8],
                      **kw)
    # widths > 16 must be tile multiples
    with pytest.raises(ValueError, match="16-token query tile"):
        CascadeEngine([TierSpec("t", cfg, fast_p)],
                      flat_buckets=[8, 24], **kw)
    # ragged requires unified execution
    with pytest.raises(ValueError, match="ragged flat"):
        CascadeEngine([TierSpec("t", cfg, fast_p)],
                      use_unified_step=False, use_ragged_step=True, **kw)
    # custom buckets are honored
    eng = CascadeEngine([TierSpec("t", cfg, fast_p)],
                        flat_buckets=[4, 16, 32], **kw)
    assert eng.runtimes[0].flat_buckets == [4, 16, 32]
    assert eng.runtimes[0].bucket_width(5) == 16


# ---------------------------------------------------------------------------
# multi-device parity (subprocess, 8 simulated host devices)
# ---------------------------------------------------------------------------


def _run(code: str, timeout=540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_ragged_parity_vs_split():
    """Acceptance: on 8 simulated devices with per-tier data meshes, the
    ragged flat engine's token streams and escalation decisions
    bit-match the single-device split engine for uniform and lognormal
    lengths — the replicated flat batch mixes correctly with the
    row-sharded page tables and KV arena."""
    out = _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock
    from repro.launch.mesh import make_tier_meshes

    assert jax.device_count() == 8, jax.device_count()
    fast = get_config("gemma3-1b", "smoke")
    exp = get_config("phi4-mini-3.8b", "smoke")
    fp = init_params(fast, jax.random.PRNGKey(0), jnp.float32)
    ep = init_params(exp, jax.random.PRNGKey(1), jnp.float32)
    vocab = min(fast.vocab_size, exp.vocab_size)

    def build(meshes, **kw):
        m = [None, None] if meshes is None else meshes
        eng = CascadeEngine(
            [TierSpec("fast", fast, fp, mesh=m[0]),
             TierSpec("exp", exp, ep, mesh=m[1])],
            deltas=[0.5], clock=VirtualClock(), **kw)
        eng.warmup()
        return eng

    def drain(eng, prompts):
        for p in prompts:
            eng.submit(np.asarray(p, np.int32), arrival_time=0.0)
        eng.run(max_steps=3000)
        return [(r.rid, tuple(r.tokens), r.tier,
                 tuple(r.seq_conf_by_tier)) for r in eng.requests]

    def check(base, other):
        assert len(base) == len(other)
        for a, b in zip(base, other):
            assert a[1] == b[1], (a, b)         # bit-identical tokens
            assert a[2] == b[2], (a, b)         # same escalation decisions
            assert np.allclose(a[3], b[3], atol=1e-5)

    rng = np.random.default_rng(7)
    PLEN, GLEN, N = 16, 4, 8
    uniform = [rng.integers(0, vocab, PLEN) for _ in range(N)]
    lens = np.clip(np.rint(rng.lognormal(np.log(PLEN / 4), 0.8, N)),
                   1, PLEN).astype(int)
    mixed = [rng.integers(0, vocab, L) for L in lens]
    kw = dict(slots=8, prompt_len=PLEN, gen_len=GLEN, prefill_chunk=8)
    for prompts in (uniform, mixed):
        meshes = make_tier_meshes([(4, 1), (4, 1)])
        split_1dev = drain(build(None, use_unified_step=False, **kw),
                           prompts)
        rag_shard = drain(build(meshes, **kw), prompts)
        check(split_1dev, rag_shard)
    print("RAGGED-PARITY-OK")
    """)
    assert "RAGGED-PARITY-OK" in out
