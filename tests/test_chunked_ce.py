"""Seq-chunked CE (§Perf iteration 8) equals the plain formulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp_compat import given, settings, st  # hypothesis or skip-stub

from repro.configs import get_config
from repro.core import losses
from repro.launch.steps import lm_loss
from repro.models import init_params


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4), st.integers(3, 40),
       st.sampled_from([4, 8, 16]))
def test_property_chunked_equals_plain(seed, b, s, chunk):
    key = jax.random.PRNGKey(seed)
    d, v = 16, 37
    hidden = jax.random.normal(key, (b, s, d))
    proj = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, v))
    labels = jax.random.randint(key, (b, s), 0, v)
    plain = losses.cross_entropy(hidden @ proj, labels)
    chunked = losses.chunked_lm_loss(hidden, proj, labels, chunk=chunk)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_gradients_match():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 12, 8, 19
    hidden = jax.random.normal(key, (b, s, d))
    proj = jax.random.normal(jax.random.PRNGKey(1), (d, v))
    labels = jax.random.randint(key, (b, s), 0, v)
    g1 = jax.grad(lambda h: losses.cross_entropy(h @ proj, labels))(hidden)
    g2 = jax.grad(lambda h: losses.chunked_lm_loss(h, proj, labels,
                                                   chunk=4))(hidden)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5,
                               atol=1e-6)


def test_lm_loss_chunked_flag():
    cfg = get_config("gemma3-1b", "smoke")
    key = jax.random.PRNGKey(0)
    p = init_params(cfg, key, jnp.float32)
    batch = {"tokens": jax.random.randint(key, (2, 33), 0, cfg.vocab_size)}
    l0, _ = lm_loss(p, cfg, batch)
    l1, _ = lm_loss(p, cfg, batch, chunked_ce=8)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
