"""Substrate tests: optimizers, data pipeline, checkpointing, classifier."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp_compat import given, settings, st  # hypothesis or skip-stub

from repro import checkpoint as ckpt
from repro.data import Batches, bigram_lm
from repro.data.synthetic import teacher_task
from repro.optim import adafactor, adamw, cosine, sgd_momentum, step_decay


def _quad_problem():
    target = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2) + jnp.sum(p["w"] ** 2)

    params = {"x": jnp.zeros(3), "w": jnp.ones((2, 2))}
    return loss, params, target


@pytest.mark.parametrize("opt_fn,lr,steps,tol", [
    (lambda: sgd_momentum(momentum=0.9), 0.05, 200, 0.05),
    (lambda: adamw(), 0.1, 200, 0.05),
    (lambda: adafactor(), 0.5, 400, 0.3),   # no momentum; sqrt-decayed lr
])
def test_optimizers_converge(opt_fn, lr, steps, tol):
    loss, params, target = _quad_problem()
    opt = opt_fn()
    state = opt.init(params)
    g = jax.jit(jax.grad(loss))
    for t in range(steps):
        lr_t = lr / np.sqrt(t + 1) if opt.name == "adafactor" else lr
        params, state = opt.update(params, g(params), state, lr_t)
    np.testing.assert_allclose(params["x"], target, atol=tol)
    np.testing.assert_allclose(params["w"], 0.0, atol=tol)


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((7,))}
    state = opt.init(params)
    assert state["v"]["big"]["vr"].shape == (64,)
    assert state["v"]["big"]["vc"].shape == (32,)
    assert state["v"]["vec"]["v"].shape == (7,)


def test_schedules():
    s = step_decay(0.1, [10, 20], 0.2)
    assert float(s(5)) == pytest.approx(0.1)
    assert float(s(15)) == pytest.approx(0.02)
    assert float(s(25)) == pytest.approx(0.004)
    c = cosine(1.0, 100, warmup=10)
    assert float(c(0)) == pytest.approx(0.0)
    assert float(c(10)) == pytest.approx(1.0, abs=0.02)
    assert float(c(100)) == pytest.approx(0.1, abs=0.02)


def test_batches_cover_epoch():
    x = np.arange(100)
    b = Batches({"x": x}, 10, seed=0)
    seen = np.concatenate([bb["x"] for bb in b.epoch()])
    assert sorted(seen.tolist()) == list(range(100))


def test_bigram_lm_has_learnable_structure():
    toks = bigram_lm(num_seqs=200, seq_len=64, vocab=64, branching=2,
                     trigram_frac=0.0, seed=0)
    # with branching=2, each token has <=2 successors
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    assert max(len(v) for v in succ.values()) <= 2


def test_teacher_task_capacity_headroom():
    ds, info = teacher_task(num_samples=2000, return_info=True)
    assert 0.5 < info["bayes_acc"] <= 1.0
    assert ds.x.shape[0] == 2000
    tr, va, te = ds.split((0.8, 0.1, 0.1))
    assert abs(tr.x.shape[0] - 1600) <= 2


def test_checkpoint_roundtrip():
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": [jnp.ones(4), jnp.zeros((2, 2))]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        ckpt.save(path, tree, step=7)
        back = ckpt.load(path, like=tree)
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                     tree, back)


def test_checkpoint_missing_key_raises():
    tree = {"a": jnp.ones(3)}
    bigger = {"a": jnp.ones(3), "b": jnp.ones(2)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        ckpt.save(path, tree)
        with pytest.raises(KeyError):
            ckpt.load(path, like=bigger)
