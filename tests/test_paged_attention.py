"""Paged flash-decode kernel + block-paged slot pool validation.

The kernel (interpret mode) is asserted against two independent
references: the gather-then-attend jnp oracle (`ref.paged_attention_ref`)
and the dense masked-arena decode path the serving engine used before
paging (`models.blocks._gqa_scores_to_out`)."""
import dataclasses as dc
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.models import blocks

KEY = jax.random.PRNGKey(0)


def _quant(x):
    sc = jnp.max(jnp.abs(x), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x / sc[..., None]), -127, 127).astype(jnp.int8)
    return q, sc


def _random_paged(seed, B, KV, G, hd, bs, max_seq, *, full_depth=False):
    """Random pool + page tables with per-row depths (never multiples of
    bs unless full_depth).  Block ids are shuffled so physical order never
    matches logical order."""
    rng = np.random.default_rng(seed)
    P = math.ceil(max_seq / bs)
    N = B * P + 1                                   # block 0 = null
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, KV, G, hd), jnp.float32)
    kp = jax.random.normal(k2, (N, bs, KV, hd), jnp.float32)
    vp = jax.random.normal(k3, (N, bs, KV, hd), jnp.float32)
    ids = list(range(1, N))
    rng.shuffle(ids)
    pt = np.zeros((B, P), np.int32)
    pos = np.zeros(B, np.int32)
    it = iter(ids)
    for b in range(B):
        pos[b] = max_seq - 1 if full_depth else rng.integers(0, max_seq)
        for j in range(pos[b] // bs + 1):
            pt[b, j] = next(it)
    return q, kp, vp, jnp.asarray(pt), jnp.asarray(pos)


@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("shape", [
    (4, 1, 4, 32, 4, 13),     # seq not a multiple of the block size
    (3, 2, 2, 64, 8, 24),
    (1, 1, 1, 16, 4, 5),      # single row, single page + remainder
])
def test_paged_kernel_matches_ref(shape, window):
    B, KV, G, hd, bs, max_seq = shape
    q, kp, vp, pt, pos = _random_paged(7, B, KV, G, hd, bs, max_seq)
    out = paged_attention(q, kp, vp, pt, pos, window=window, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, pt, pos, window=window)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 6])
def test_paged_kernel_int8_scales_match_ref(window):
    B, KV, G, hd, bs, max_seq = 4, 2, 3, 32, 4, 15
    q, kp, vp, pt, pos = _random_paged(11, B, KV, G, hd, bs, max_seq)
    kq, ks = _quant(kp)
    vq, vs = _quant(vp)
    out = paged_attention(q, kq, vq, pt, pos, k_scale=ks, v_scale=vs,
                          window=window, interpret=True)
    want = ref.paged_attention_ref(q, kq, vq, pt, pos, k_scale=ks,
                                   v_scale=vs, window=window)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 9])
def test_paged_kernel_matches_dense_decode_reference(window):
    """Gathering the pool through the page tables must reproduce the
    dense masked-arena decode (`_gqa_scores_to_out`) the engine used
    before paging — per-row positions, shuffled physical blocks."""
    B, KV, G, hd, bs, max_seq = 5, 2, 2, 32, 4, 19
    q, kp, vp, pt, pos = _random_paged(3, B, KV, G, hd, bs, max_seq)
    out = paged_attention(q, kp, vp, pt, pos, window=window, interpret=True)

    # densify: row b's token t lives at (pt[b, t//bs], t % bs)
    T = pt.shape[1] * bs
    t = np.arange(T)
    blk = np.asarray(pt)[:, t // bs]
    k_dense = np.asarray(kp)[blk, t % bs]           # [B, T, KV, hd]
    v_dense = np.asarray(vp)[blk, t % bs]
    idx = jnp.arange(T)[None, None, None, None, :]
    pb = pos[:, None, None, None, None]
    mask = idx <= pb
    if window is not None:
        mask &= idx > pb - window
    want = blocks._gqa_scores_to_out(
        q[:, None], jnp.asarray(k_dense), jnp.asarray(v_dense), mask)
    np.testing.assert_allclose(out, want[:, 0], rtol=2e-5, atol=2e-5)


def test_paged_kernel_null_pages_never_attended():
    """Poisoning every unmapped (null-padded) page table entry's block
    must not change the output: positions past `pos` are masked and
    unmapped pages are skipped."""
    B, KV, G, hd, bs, max_seq = 3, 1, 2, 16, 4, 16
    q, kp, vp, pt, pos = _random_paged(5, B, KV, G, hd, bs, max_seq)
    base = paged_attention(q, kp, vp, pt, pos, interpret=True)
    kp2 = kp.at[0].set(1e6)                         # poison the null block
    vp2 = vp.at[0].set(1e6)
    out = paged_attention(q, kp2, vp2, pt, pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


# ---------------------------------------------------------------------------
# model-level: paged decode_step == dense decode_step
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_cfg_params():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


@pytest.mark.parametrize("kv_quant", [None, "int8"])
def test_decode_step_paged_matches_dense(smoke_cfg_params, kv_quant):
    """transformer.decode_step over a block-paged cache must produce the
    same logits as the dense cache path, rows at staggered depths."""
    from repro.models import cache as cache_lib
    from repro.models import transformer
    cfg, params = smoke_cfg_params
    cfg = dc.replace(cfg, kv_quant=kv_quant)
    B, prompt, bs, max_seq = 3, 9, 4, 14
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, prompt)),
                       jnp.int32)
    _, part, _ = transformer.forward(params, cfg, {"tokens": toks},
                                     mode="prefill")

    # dense arena
    dense = cache_lib.init_cache(cfg, B, max_seq, jnp.float32)

    def put(full, piece):
        idx = tuple(slice(0, d) for d in piece.shape)
        return full.at[idx].set(piece.astype(full.dtype))
    dense = jax.tree.map(put, dense, part)

    # paged arena via the tier pool (shuffles nothing, but exercises the
    # prefill scatter path)
    from repro.serving.slots import TierSlotPool
    pool = TierSlotPool(cfg, B, max_seq, block_size=bs)
    for slot in range(B):
        pool.bind(slot, prompt)
    pool.write_prefill(list(range(B)), part)

    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    pos = jnp.full((B, 1), prompt, jnp.int32)
    logits_d, _ = transformer.decode_step(params, cfg, tok, dense, pos)
    pt = jnp.asarray(pool.page_table)
    logits_p, _ = transformer.decode_step(params, cfg, tok, pool.cache, pos,
                                          pages={"page_table": pt})
    # int8: the dense path feeds bf16-cast K/V to the dots while the
    # kernel dequantizes in f32, so agreement is at quantization noise
    tol = 2e-4 if kv_quant is None else 2e-2
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=tol, atol=tol)
    assert np.array_equal(np.argmax(np.asarray(logits_p)[:, 0], -1),
                          np.argmax(np.asarray(logits_d)[:, 0], -1))
