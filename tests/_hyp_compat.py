"""Graceful degradation when `hypothesis` is not installed.

Property-based tests import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly.  With hypothesis available this module is
a pure re-export; without it, ``@given`` turns the test into a skip (reason
recorded) and the deterministic tests in the same module keep running —
the suite degrades instead of failing at collection.
"""
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements.txt)")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning an inert placeholder (the decorated test is
        skipped, so strategies are never drawn from)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
