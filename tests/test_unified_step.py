"""Unified token-batch execution: the mixed-attention kernel vs its
oracle, unified-vs-split engine parity (single-device and 8 simulated
sharded devices), the one-launch/one-sync-per-tier-per-tick guarantee,
and the scheduler's one-currency admission edges.

The engine parity tests assert **bit-identical token streams and
escalation decisions** between the unified backend (one compiled mixed
prefill+decode program per tier per tick, ``use_unified_step=True``) and
the legacy split backend (``use_unified_step=False``; chunk_fn + step_fn,
two launches on mixed ticks) across uniform, lognormal, and
over-subscribed workloads; confidences to 1e-5 (the two paths batch the
same per-row math at different widths, which cannot reassociate a row's
reductions but may differ by ulps in vectorized lowering).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.serving import CascadeEngine, CascadeScheduler, TierSpec
from repro.serving.engine import StepPlan, VirtualClock  # noqa: F401
from repro.serving.request import Request, RequestState

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# kernel vs jnp oracle
# ---------------------------------------------------------------------------


def _rand_pool(rng, B, C, KV, G, hd, N, bs, P, quant=False):
    q = jnp.asarray(rng.standard_normal((B, C, KV, G, hd)), jnp.float32)
    pt = jnp.asarray(rng.integers(1, N, (B, P)), jnp.int32)
    if quant:
        k = jnp.asarray(rng.integers(-127, 128, (N, bs, KV, hd)), jnp.int8)
        v = jnp.asarray(rng.integers(-127, 128, (N, bs, KV, hd)), jnp.int8)
        ks = jnp.asarray(rng.uniform(0.01, 0.05, (N, bs, KV)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.01, 0.05, (N, bs, KV)), jnp.float32)
        return q, k, v, pt, ks, vs
    k = jnp.asarray(rng.standard_normal((N, bs, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((N, bs, KV, hd)), jnp.float32)
    return q, k, v, pt, None, None


@pytest.mark.parametrize("window", [None, 6])
def test_mixed_kernel_matches_oracle(window):
    """One batch mixing every row kind the engine plans: a full prefill
    chunk, a final-chunk tail, a decode row (q_len=1 at depth), and a
    stalled/idle row (q_len=0, exact-zero output)."""
    rng = np.random.default_rng(0)
    B, C, KV, G, hd = 4, 8, 2, 2, 16
    N, bs, P = 11, 4, 6
    q, k, v, pt, _, _ = _rand_pool(rng, B, C, KV, G, hd, N, bs, P)
    start = jnp.asarray([0, 5, 13, 9], jnp.int32)
    qlen = jnp.asarray([8, 3, 1, 0], jnp.int32)     # chunk/tail/decode/stall
    got = kernel_ops.mixed_attention(
        q, k, v, pt, start, qlen, window=window, interpret=True)
    want = ref.mixed_attention_ref(
        q, k, v, pt, start, qlen, window=window)
    for b in range(B):
        n = int(qlen[b])
        np.testing.assert_allclose(np.asarray(got)[b, :n],
                                   np.asarray(want)[b, :n],
                                   rtol=2e-5, atol=2e-6)
    np.testing.assert_array_equal(np.asarray(got)[3], 0.0)


def test_mixed_kernel_decode_row_matches_paged_decode_oracle():
    """A q_len=1 row in the mixed batch IS a paged flash-decode step:
    its slot-0 output must match the decode kernel's oracle at the same
    position/page table."""
    rng = np.random.default_rng(3)
    B, C, KV, G, hd = 3, 4, 2, 3, 8
    N, bs, P = 9, 4, 5
    q, k, v, pt, _, _ = _rand_pool(rng, B, C, KV, G, hd, N, bs, P)
    pos = jnp.asarray([7, 0, 18], jnp.int32)
    qlen = jnp.ones(B, jnp.int32)
    got = kernel_ops.mixed_attention(q, k, v, pt, pos, qlen, interpret=True)
    want = ref.paged_attention_ref(q[:, 0], k, v, pt, pos)
    np.testing.assert_allclose(np.asarray(got)[:, 0], np.asarray(want),
                               rtol=2e-5, atol=2e-6)


def test_mixed_kernel_int8_dequant_matches_oracle():
    rng = np.random.default_rng(1)
    B, C, KV, G, hd = 3, 4, 1, 3, 8
    N, bs, P = 9, 4, 4
    q, k, v, pt, ks, vs = _rand_pool(rng, B, C, KV, G, hd, N, bs, P,
                                     quant=True)
    start = jnp.asarray([2, 9, 5], jnp.int32)
    qlen = jnp.asarray([4, 1, 2], jnp.int32)        # chunk, decode, tail
    got = kernel_ops.mixed_attention(
        q, k, v, pt, start, qlen, k_scale=ks, v_scale=vs, interpret=True)
    want = ref.mixed_attention_ref(
        q, k, v, pt, start, qlen, k_scale=ks, v_scale=vs)
    for b in range(B):
        n = int(qlen[b])
        np.testing.assert_allclose(np.asarray(got)[b, :n],
                                   np.asarray(want)[b, :n],
                                   rtol=2e-5, atol=2e-6)


def test_mixed_kernel_sliding_window_decode_row():
    """Sliding window applies per absolute query position, so a deep
    decode row (q_len=1) only attends its trailing window through the
    shared page gather."""
    rng = np.random.default_rng(2)
    B, C, KV, G, hd = 2, 4, 2, 2, 8
    N, bs, P = 9, 4, 5
    q, k, v, pt, _, _ = _rand_pool(rng, B, C, KV, G, hd, N, bs, P)
    pos = jnp.asarray([17, 3], jnp.int32)
    qlen = jnp.asarray([1, 4], jnp.int32)
    got = kernel_ops.mixed_attention(q, k, v, pt, pos, qlen, window=5,
                                     interpret=True)
    want = ref.mixed_attention_ref(q, k, v, pt, pos, qlen, window=5)
    for b in range(B):
        n = int(qlen[b])
        np.testing.assert_allclose(np.asarray(got)[b, :n],
                                   np.asarray(want)[b, :n],
                                   rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# engine: unified vs split parity (single device)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_parts():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    fast_p = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    exp_p = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    return cfg, fast_p, exp_p


def _mk(cfg, fast_p, exp_p, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("prompt_len", 16)
    kw.setdefault("gen_len", 4)
    kw.setdefault("deltas", [0.5])
    kw.setdefault("kv_block_size", 4)
    kw.setdefault("prefill_chunk", 5)
    kw.setdefault("clock", VirtualClock())
    return CascadeEngine([TierSpec("fast", cfg, fast_p),
                          TierSpec("exp", cfg, exp_p)], **kw)


def _drain(eng, prompts, arrivals=None):
    for i, p in enumerate(prompts):
        t = 0.0 if arrivals is None else float(arrivals[i])
        eng.submit(p, arrival_time=t)
    eng.run(max_steps=1000)
    assert all(r.state is RequestState.DONE for r in eng.requests)
    return eng


def _check_streams(a_eng, b_eng):
    for a, b in zip(a_eng.requests, b_eng.requests):
        assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)
        assert a.tier == b.tier
        np.testing.assert_allclose(a.token_conf, b.token_conf, rtol=1e-5)


def test_unified_matches_split_mixed_lengths(tiny_parts):
    """Acceptance: the unified token-batch engine emits token streams
    bit-identical to the split-path engine over mixed prompt lengths
    (incl. 1, chunk boundaries, and max_prompt_len) with staggered
    arrivals."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(0)
    lens = [1, 3, 5, 6, 10, 16]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    # probe pass: a fixed δ at the widest confidence gap, so the gate
    # genuinely splits traffic across both tiers in the parity runs
    probe = _drain(_mk(cfg, fast_p, exp_p), prompts)
    confs = sorted(r.seq_conf_by_tier[0] for r in probe.requests)
    gaps = [(confs[i + 1] - confs[i], i) for i in range(len(confs) - 1)]
    _, i = max(gaps)
    delta = 0.5 * (confs[i] + confs[i + 1])
    uni = _drain(_mk(cfg, fast_p, exp_p, deltas=[delta]), prompts,
                 arrivals=[i % 3 for i in range(len(prompts))])
    assert uni.unified_step and all(rt.unified for rt in uni.runtimes)
    spl = _drain(_mk(cfg, fast_p, exp_p, deltas=[delta],
                     use_unified_step=False), prompts,
                 arrivals=[i % 3 for i in range(len(prompts))])
    assert not spl.unified_step
    _check_streams(uni, spl)
    assert {r.tier for r in uni.requests} == {0, 1}     # gate really splits


def test_unified_matches_split_oversubscribed_arena(tiny_parts):
    """Stalls (block exhaustion) may reorder work under the unified
    planner but never change tokens or escalation decisions vs the split
    engine on the same over-subscribed arena."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(7)
    lens = [2, 16, 7, 11, 16, 4, 9, 1]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    kw = dict(slots=4, prefill_chunk=4, kv_blocks=[12, None])
    uni = _drain(_mk(cfg, fast_p, exp_p, **kw), prompts)
    spl = _drain(_mk(cfg, fast_p, exp_p, use_unified_step=False, **kw),
                 prompts)
    _check_streams(uni, spl)


def test_unified_gen_len_one_emits_exactly_one_token(tiny_parts):
    """A row finishing prefill emits its first token from the mixed
    batch; gen_len=1 requests must end there with exactly one token,
    identical to the split and uniform one-shot paths."""
    cfg, fast_p, exp_p = tiny_parts
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 8, 16)]
    runs = []
    for kw in ({}, {"use_unified_step": False}):
        eng = _drain(_mk(cfg, fast_p, exp_p, gen_len=1, **kw), prompts)
        assert all(len(r.tokens) == 1 for r in eng.requests)
        runs.append(eng)
    _check_streams(*runs)


def test_unified_step_requires_chunked_prefill(tiny_parts):
    from repro.configs import get_config
    from repro.models import init_params
    cfg, fast_p, _ = tiny_parts
    with pytest.raises(ValueError, match="unified token-batch"):
        CascadeEngine([TierSpec("t", cfg, fast_p)], slots=2, prompt_len=8,
                      gen_len=2, deltas=[], use_paged_kv=False,
                      use_unified_step=True)
    jcfg = get_config("jamba-v0.1-52b", "smoke")    # mamba: recurrent
    jp = init_params(jcfg, jax.random.PRNGKey(0), jnp.float32)
    with pytest.raises(ValueError, match="unified token-batch"):
        CascadeEngine([TierSpec("t", jcfg, jp)], slots=2, prompt_len=8,
                      gen_len=2, deltas=[], use_unified_step=True)
    # auto mode falls back to the split path for recurrent models
    eng = CascadeEngine([TierSpec("t", jcfg, jp)], slots=2, prompt_len=8,
                        gen_len=2, deltas=[])
    assert not eng.unified_step and not eng.runtimes[0].unified


# ---------------------------------------------------------------------------
# one launch + one device_get per active tier per tick
# ---------------------------------------------------------------------------


def _one_tier_engine(**kw):
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("gemma3-1b", "smoke")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return CascadeEngine([TierSpec("t", cfg, params)], slots=4,
                         prompt_len=32, gen_len=4, prefill_chunk=8,
                         clock=VirtualClock(), **kw)


def test_mixed_tick_pays_one_launch_and_one_sync():
    """Acceptance: a tick advancing prefill chunks AND decoding must
    execute exactly ONE compiled program and ONE device_get for the
    tier — the whole point of unified token-batch execution (the split
    path pays two launches on the same tick)."""
    eng = _one_tier_engine()
    eng.warmup()
    assert eng.host_syncs == 0
    eng.submit(np.arange(32, dtype=np.int32) % 7)       # 4 chunks
    eng.step()                          # chunk 1: launch, nothing to emit
    assert eng.metrics.launches_by_tier == [1]
    assert eng.host_syncs == 0          # no emits -> fetch skipped
    eng.submit(np.arange(6, dtype=np.int32) % 5)
    eng.step()                          # long chunk 2 + short finishes
    assert eng.metrics.launches_by_tier == [2]
    assert eng.host_syncs == 1
    launches, syncs = eng.metrics.launches_by_tier[0], eng.host_syncs
    eng.step()                          # long chunk 3 + short DECODES:
    assert eng.metrics.launches_by_tier == [launches + 1]   # one program,
    assert eng.host_syncs == syncs + 1                      # one fetch
    eng.run(max_steps=100)
    assert all(len(r.tokens) == 4 for r in eng.requests)
    s = eng.metrics.summary()
    assert s["launches"] == [eng.metrics.launches_by_tier[0]]
    assert s["host_syncs"] == [eng.host_syncs]
    assert max(s["launches_per_tick"]) <= 1.0 + 1e-9


def test_split_mixed_tick_pays_two_launches():
    """The A/B baseline the unified path fuses away: the split backend
    dispatches chunk_fn AND step_fn on a mixed prefill+decode tick."""
    eng = _one_tier_engine(use_unified_step=False)
    eng.warmup()
    eng.submit(np.arange(32, dtype=np.int32) % 7)
    eng.step()
    eng.submit(np.arange(6, dtype=np.int32) % 5)
    eng.step()                          # short finishes + same-tick decode
    launches = eng.metrics.launches_by_tier[0]
    eng.step()                          # long chunk + short decode: TWO
    assert eng.metrics.launches_by_tier == [launches + 2]
    eng.run(max_steps=100)


# ---------------------------------------------------------------------------
# StepPlan builder
# ---------------------------------------------------------------------------


def test_step_plan_records_per_row_kind_qlen_pos_shard():
    """The plan is the tick's host-side record: a mid-prefill row carries
    q_len=chunk at its chunk start, a decode row q_len=1 at its decode
    position with its own token in slot 0, idle rows q_len=0 — and kind/
    shard mirror those decisions per row."""
    from repro.serving.engine import (KIND_DECODE, KIND_IDLE, KIND_PREFILL)
    eng = _one_tier_engine()                # slots=4, chunk=8, plen<=32
    eng.warmup()
    eng.submit(np.arange(6, dtype=np.int32) % 5)        # finishes tick 1
    eng.step()
    eng.submit(np.arange(20, dtype=np.int32) % 7)       # 3 chunks
    eng.step()                              # admit long; short decodes
    rt = eng.runtimes[0]
    plan = eng._build_plan(rt)
    assert plan.width == rt.chunk
    [dec] = plan.decode_rows
    [pre] = plan.prefill_rows
    assert plan.kind[dec] == KIND_DECODE and plan.q_len[dec] == 1
    assert plan.tokens[dec, 0] == rt.tok[dec]
    assert plan.pos[dec, 0] == rt.pos[dec]
    assert plan.kind[pre] == KIND_PREFILL
    assert plan.q_len[pre] == rt.chunk      # second chunk of the long row
    assert plan.pos[pre, 0] == rt.prefill_pos[pre] == rt.chunk
    assert not plan.finishing
    idle = [s for s in range(rt.capacity) if s not in (dec, pre)]
    assert all(plan.kind[s] == KIND_IDLE and plan.q_len[s] == 0
               for s in idle)
    assert all(plan.shard[s] == rt.pool.shard_of(s)
               for s in (dec, pre))


# ---------------------------------------------------------------------------
# scheduler admission edges: one token currency
# ---------------------------------------------------------------------------


def _req(rid, plen, arrival=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32), gen_len=2,
                   arrival_time=arrival)


def test_scheduler_token_cost_charges_first_chunk():
    """token_cost= lets admission bill a request's first chunk instead of
    its whole prompt (later chunks bill later ticks' windows)."""
    sched = CascadeScheduler([8], [])
    for i, plen in enumerate([10, 10, 10]):
        sched.submit(_req(i, plen))
    got, _ = sched.admit(0, now=0.0, token_budget=9,
                         token_cost=lambda r: min(4, r.prompt_tokens),
                         admitted_before=0)
    assert [r.rid for r in got] == [0, 1]       # 4+4 fits, +4 would not


def test_scheduler_carried_load_shares_the_budget():
    """budget_used pre-charged with the tick's decode+chunk load throttles
    admission: prefill chunks and decode tokens are one currency."""
    sched = CascadeScheduler([8], [])
    for i in range(3):
        sched.submit(_req(i, 4))
    # carried load 6 of a 14-token budget: the first is admitted by the
    # never-starve guard (6+4=10), the second fits exactly (10+4=14),
    # the third would overflow (14+4 > 14)
    got, _ = sched.admit(0, now=0.0, token_budget=14, budget_used=6,
                         token_cost=lambda r: r.prompt_tokens,
                         admitted_before=0)
    assert [r.rid for r in got] == [0, 1]


def test_scheduler_first_request_never_starves_under_carried_load():
    """A prompt longer than the whole budget — or a window whose carried
    decode load already exceeds it — must still admit the window's first
    request (admitted_before=0); the legacy budget_used>0 rule would
    starve it forever."""
    sched = CascadeScheduler([4], [])
    sched.submit(_req(0, 100))                  # longer than the budget
    sched.submit(_req(1, 4))
    got, _ = sched.admit(0, now=0.0, token_budget=16, budget_used=10,
                         admitted_before=0)
    assert [r.rid for r in got] == [0]          # first always admitted
    got, _ = sched.admit(0, now=0.0, token_budget=16, budget_used=110,
                         admitted_before=1)
    assert got == []                            # the rest must fit
    got, _ = sched.admit(0, now=0.0, token_budget=16, budget_used=3,
                         admitted_before=1)
    assert [r.rid for r in got] == [1]


def test_scheduler_shard_pinned_admission_with_full_shard():
    """admit(shard=) must not spill onto other shards: a full shard
    admits nothing even while the other shard has free rows."""
    sched = CascadeScheduler([4], [], shards_per_tier=[2])
    for i in range(4):
        sched.submit(_req(i, 4))
    got, slots = sched.admit(0, now=0.0, shard=1)
    assert [sched.allocators[0].shard_of(s) for s in slots] == [1, 1]
    assert sched.admit(0, now=0.0, shard=1) == ([], [])     # shard 1 full
    assert sched.peek(0, now=0.0) is not None   # head still waiting
    got, slots = sched.admit(0, now=0.0, shard=0)
    assert [sched.allocators[0].shard_of(s) for s in slots] == [0, 0]
    assert sched.pending == 0


def test_engine_budget_spans_prefill_and_decode(tiny_parts):
    """Engine-level one-currency acceptance: rows decoding this tick
    consume the same token budget admission draws from, so a tier
    admits less while it decodes (the split path's prefill-only window
    admits more)."""
    cfg, fast_p, exp_p = tiny_parts

    def occupied_per_tick(**kw):
        eng = _mk(cfg, fast_p, exp_p, slots=6, prompt_len=8,
                  prefill_chunk=8, prefill_token_budget=17,
                  deltas=[-1.0], **kw)          # nothing escalates
        rng = np.random.default_rng(0)
        for _ in range(4):
            eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32))
        occ = []
        for t in range(2):
            eng.step(float(t))
            eng.clock.step_done()
            occ.append(len(eng.runtimes[0].occupied()))
        eng.run(max_steps=200)
        assert all(r.state is RequestState.DONE for r in eng.requests)
        return occ

    # unified: tick 0 admits two 8-token prompts (16 <= 17); tick 1
    # carries 2 decode tokens, so only the never-starve head fits
    # (2+8=10, +8=18 > 17) -> 3 occupied
    assert occupied_per_tick() == [2, 3]
    # split window ignores the decode load: both remaining admitted
    assert occupied_per_tick(use_unified_step=False) == [2, 4]


# ---------------------------------------------------------------------------
# multi-device parity (subprocess, 8 simulated host devices)
# ---------------------------------------------------------------------------


def _run(code: str, timeout=540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_unified_parity_vs_split():
    """Acceptance: on 8 simulated devices with per-tier data meshes, the
    unified engine's token streams and escalation decisions bit-match
    the split-path engine (sharded and single-device) for uniform and
    lognormal prompt lengths and for an over-subscribed arena."""
    out = _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock
    from repro.launch.mesh import make_tier_meshes

    assert jax.device_count() == 8, jax.device_count()
    fast = get_config("gemma3-1b", "smoke")
    exp = get_config("phi4-mini-3.8b", "smoke")
    fp = init_params(fast, jax.random.PRNGKey(0), jnp.float32)
    ep = init_params(exp, jax.random.PRNGKey(1), jnp.float32)
    vocab = min(fast.vocab_size, exp.vocab_size)

    def build(meshes, unified, **kw):
        m = [None, None] if meshes is None else meshes
        eng = CascadeEngine(
            [TierSpec("fast", fast, fp, mesh=m[0]),
             TierSpec("exp", exp, ep, mesh=m[1])],
            deltas=[0.5], use_unified_step=unified,
            clock=VirtualClock(), **kw)
        eng.warmup()
        return eng

    def drain(eng, prompts):
        for p in prompts:
            eng.submit(np.asarray(p, np.int32), arrival_time=0.0)
        eng.run(max_steps=3000)
        return [(r.rid, tuple(r.tokens), r.tier,
                 tuple(r.seq_conf_by_tier)) for r in eng.requests]

    def check(base, other):
        assert len(base) == len(other)
        for a, b in zip(base, other):
            assert a[1] == b[1], (a, b)         # bit-identical tokens
            assert a[2] == b[2], (a, b)         # same escalation decisions
            assert np.allclose(a[3], b[3], atol=1e-5)

    rng = np.random.default_rng(7)
    PLEN, GLEN, N = 16, 4, 8
    uniform = [rng.integers(0, vocab, PLEN) for _ in range(N)]
    lens = np.clip(np.rint(rng.lognormal(np.log(PLEN / 4), 0.8, N)),
                   1, PLEN).astype(int)
    mixed = [rng.integers(0, vocab, L) for L in lens]
    kw = dict(slots=8, prompt_len=PLEN, gen_len=GLEN, prefill_chunk=8)
    for prompts in (uniform, mixed):
        meshes = make_tier_meshes([(4, 1), (4, 1)])
        split_1dev = drain(build(None, False, **kw), prompts)
        uni_shard = drain(build(meshes, True, **kw), prompts)
        check(split_1dev, uni_shard)

    # over-subscribed sharded arena (6 blocks/shard = one full request)
    kw = dict(slots=8, prompt_len=PLEN, gen_len=GLEN, prefill_chunk=8,
              kv_block_size=4, kv_blocks=24)
    meshes = make_tier_meshes([(4, 1), (4, 1)])
    split_1dev = drain(build(None, False, **kw), mixed)
    uni_shard = drain(build(meshes, True, **kw), mixed)
    check(split_1dev, uni_shard)
    print("UNIFIED-PARITY-OK")
    """)
    assert "UNIFIED-PARITY-OK" in out
