"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward + one train step on CPU; output
shapes asserted, no NaNs.  Also: decode == full-forward cache consistency
for one representative of each mixer family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.launch import steps as steps_lib
from repro.models import (decode_step, forward, init_cache, init_params)

B, S = 2, 32


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, "smoke")
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    for layer in cfg.layers:
        if layer.ffn.kind == "moe":
            assert layer.ffn.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, jnp.float32)
    batch = _batch(cfg, key)

    logits, _, aux = forward(params, cfg, batch, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()

    train_step, opt = steps_lib.make_train_step(cfg, lr=1e-2)
    opt_state = opt.init(params)
    params2, opt_state2, metrics = jax.jit(train_step)(params, opt_state,
                                                       batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    changed = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                           params, params2)
    assert max(jax.tree.leaves(changed)) > 0.0


@pytest.mark.parametrize("arch", ["gemma3-1b", "jamba-v0.1-52b", "rwkv6-3b",
                                  "granite-moe-3b-a800m", "qwen2-vl-72b"])
def test_decode_matches_full_forward(arch):
    """Prefill S-1 tokens, decode token S-1: logits must equal the full
    forward's position S-1 (cache correctness across all mixer kinds)."""
    cfg = get_config(arch, "smoke")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key, jnp.float32)
    batch = _batch(cfg, key)
    toks = batch["tokens"]

    full_logits, _, _ = forward(params, cfg, batch, mode="train")

    pre = dict(batch)
    pre["tokens"] = toks[:, :S - 1]
    _, part_cache, _ = forward(params, cfg, pre, mode="prefill")
    cache = init_cache(cfg, B, S, jnp.float32)

    def put(full, part):
        if full.shape == part.shape:
            return part.astype(full.dtype)
        return full.at[tuple(slice(0, d) for d in part.shape)].set(
            part.astype(full.dtype))

    cache = jax.tree.map(put, cache, part_cache)
    pos = jnp.full((B, 1), S - 1, jnp.int32)
    dec_logits, _ = decode_step(params, cfg, toks[:, S - 1:S], cache, pos)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)


def test_early_exit_heads():
    """Model-splitting support: exit logits per period + Eq 6 trains."""
    import dataclasses
    from repro.core import losses

    cfg = get_config("gemma3-1b", "smoke")
    cfg = dataclasses.replace(cfg, num_periods=3, early_exit_periods=(0, 1))
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key, jnp.float32)
    batch = _batch(cfg, key)
    logits, _, aux = forward(params, cfg, batch, mode="train")
    assert "exit_logits" in aux and len(aux["exit_logits"]) == 2
    for el in aux["exit_logits"]:
        assert el.shape == (B, S, cfg.vocab_size)
    chain = [el[:, :-1] for el in aux["exit_logits"]] + [logits[:, :-1]]
    labels = batch["tokens"][:, 1:]
    loss, _ = losses.ltc_chain_loss(chain, labels, w=1.0)
    assert np.isfinite(float(loss))


def test_ltc_train_step_decreases_cascade_loss():
    """A few LtC steps on a fixed batch should reduce Eq 4's loss."""
    fast_cfg = get_config("gemma3-1b", "smoke")
    exp_cfg = get_config("phi4-mini-3.8b", "smoke")
    key = jax.random.PRNGKey(3)
    fast_p = init_params(fast_cfg, key, jnp.float32)
    exp_p = init_params(exp_cfg, jax.random.PRNGKey(4), jnp.float32)
    vocab = min(fast_cfg.vocab_size, exp_cfg.vocab_size)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, vocab)}

    step, opt = steps_lib.make_ltc_train_step(fast_cfg, exp_cfg, w=1.0,
                                              lr=5e-3)
    step = jax.jit(step)
    state = opt.init(fast_p)
    losses_seen = []
    for _ in range(10):
        fast_p, state, m = step(fast_p, state, exp_p, batch)
        losses_seen.append(float(m["l_org"] + m["l_casc"]))
    assert losses_seen[-1] < losses_seen[0], losses_seen
