"""scripts/check_docs.py: the doc-reference checker must pass on the
repo's real docs and fail on deliberately broken references."""
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "scripts"))

import check_docs  # noqa: E402


def test_repo_docs_have_no_broken_references():
    errors = []
    for doc in check_docs.default_docs(ROOT):
        errors.extend(check_docs.check_file(doc, ROOT))
    assert errors == []


def test_missing_file_reference_fails():
    errs = check_docs.check_text(
        "see `serving/engine.py` and `serving/no_such_module.py`", ROOT)
    assert len(errs) == 1 and "no_such_module.py" in errs[0]


def test_missing_symbol_reference_fails():
    ok = check_docs.check_text(
        "`serving/engine.py::CascadeEngine` and "
        "`core/server.py::delta_for_escalation_rate`", ROOT)
    assert ok == []
    errs = check_docs.check_text(
        "`serving/engine.py::TotallyMadeUpSymbol`", ROOT)
    assert len(errs) == 1 and "TotallyMadeUpSymbol" in errs[0]


def test_dotted_symbol_components_are_all_checked():
    assert check_docs.check_text(
        "`serving/slots.py::TierSlotPool.ensure_blocks`", ROOT) == []
    errs = check_docs.check_text(
        "`serving/slots.py::TierSlotPool.frobnicate`", ROOT)
    assert len(errs) == 1 and "frobnicate" in errs[0]


def test_urls_and_globs_are_ignored():
    assert check_docs.check_text(
        "fetch https://example.com/missing/thing.py and scan `docs/*.md`",
        ROOT) == []


def test_absolute_output_paths_are_ignored():
    # output placeholders like `--trace-out /tmp/trace.json` are not
    # repo references; relative ones still fail
    assert check_docs.check_text(
        "run with `--trace-out /tmp/trace.json`", ROOT) == []
    assert check_docs.check_text(
        "run with `--trace-out trace.json`", ROOT)


def test_root_and_src_relative_paths_resolve():
    text = ("`README.md` `benchmarks/serving_throughput.py` "
            "`repro/serving/engine.py` `kernels/prefill_attention.py`")
    assert check_docs.check_text(text, ROOT) == []


def test_main_exit_codes(tmp_path):
    good = tmp_path / "good.md"
    good.write_text("nothing to see\n")
    bad = tmp_path / "bad.md"
    bad.write_text("look at `definitely/not/a/file.py`\n")
    assert check_docs.main([str(good)]) == 0
    assert check_docs.main([str(good), str(bad)]) == 1


def test_find_refs_extracts_lineno_and_symbol():
    refs = check_docs.find_refs(
        "a\n`core/losses.py::ltc_loss` then `docs/serving.md`\n")
    assert refs == [(2, "core/losses.py", "ltc_loss"),
                    (2, "docs/serving.md", None)]
