"""Sharded multi-tier serving: shard-aware allocators (host-side units)
and the multi-device parity suite.

The parity tests run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
tests/test_dryrun_small.py pattern, so the main pytest process keeps its
single real device) and assert that the engine on per-tier meshes —
params placed per tier, request rows and the paged KV block pool sharded
over each mesh's data axis — produces **bit-identical token streams and
identical escalation decisions** to the single-device engine, for
uniform and lognormal prompt lengths and for an over-subscribed sharded
arena.  Confidences are compared to 1e-6: GSPMD partitioning may reorder
float reductions by a few ulps, which greedy argmax and the fixed-δ gate
absorb.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.serving.slots import BlockAllocator, SlotAllocator, TierSlotPool

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- shard-aware allocators (no devices needed) -----------------------------


def test_slot_allocator_sharded_ranges():
    a = SlotAllocator(8, shards=2)
    assert a.shard_of(3) == 0 and a.shard_of(4) == 1
    assert a.free_in(0) == a.free_in(1) == 4
    # pinned alloc stays in the shard's contiguous range, ascending first
    assert [a.alloc(1) for _ in range(4)] == [4, 5, 6, 7]
    assert a.alloc(1) is None           # shard 1 exhausted
    assert a.free_in(0) == 4            # shard 0 untouched
    s = a.alloc(0)
    assert s == 0
    a.free(5)
    assert a.free_in(1) == 1 and a.alloc(1) == 5   # LIFO within shard
    # balanced alloc picks the shard with most free rows
    assert a.shard_of(a.alloc(None)) == 0


def test_slot_allocator_shard_divisibility():
    with pytest.raises(ValueError):
        SlotAllocator(6, shards=4)


def test_slot_allocator_unsharded_matches_legacy():
    a = SlotAllocator(4)
    assert [a.alloc() for _ in range(4)] == [0, 1, 2, 3]
    a.free(1)
    a.free(2)
    assert a.alloc() == 2               # LIFO free list, as before


def test_block_allocator_sharded_null_block():
    b = BlockAllocator(8, shards=2)
    # shard 0 owns ids 0..3 but never hands out the null block 0
    assert b.free_in(0) == 3 and b.free_in(1) == 4
    got = [b.alloc(0) for _ in range(3)]
    assert got == [1, 2, 3]
    assert b.alloc(0) is None
    assert b.shard_of(b.alloc(1)) == 1
    assert b.high_water_by_shard == [3, 1]
    b.free(2)
    assert b.free_in(0) == 1
    assert b.high_water_by_shard == [3, 1]      # high water sticks


def test_block_allocator_unsharded_matches_legacy():
    b = BlockAllocator(4)
    assert [b.alloc() for _ in range(3)] == [1, 2, 3]
    assert b.alloc() is None
    assert b.high_water == 3 and b.high_water_by_shard == [3]


def test_tier_slot_pool_sharded_accounting():
    """Rows and blocks partition per shard; the oldest-first reserve is
    enforced within a shard, not across shards."""
    from repro.configs import get_config
    cfg = get_config("gemma3-1b", "smoke")
    # 4 rows / 2 shards, block_size 4, max_seq 16 -> 4 pages per row;
    # 10 blocks round up to 10 (already even): shard 0 usable 4, shard 1: 5
    pool = TierSlotPool(cfg, 4, 16, block_size=4, num_blocks=10,
                        data_shards=2)
    assert pool.data_shards == 2 and pool.num_blocks == 10
    assert pool.shard_of(1) == 0 and pool.shard_of(2) == 1
    # shard 1's blocks come from its own range [5, 10)
    assert pool.can_admit(8, shard=1)
    pool.bind(2, 8, row_tokens=16)      # slot 2 = shard 1, 2 blocks
    assert all(pool.shard_of_block(b) == 1 for b in pool._row_blocks[2])
    # shard 0 is independent: full demand there is still admissible
    assert pool.can_admit(8, shard=0)
    pool.bind(0, 8, row_tokens=16)
    assert all(pool.shard_of_block(b) == 0 for b in pool._row_blocks[0])
    # shard 1: second row must leave the oldest row's remaining demand
    # (2 more blocks) free: 5 - 2 bound = 3 free, a 2-block prompt would
    # leave only 1 -> denied; a 1-block prompt leaves 2 -> admitted
    assert not pool.can_admit(8, shard=1)
    assert pool.can_admit(4, shard=1)
    # growth beyond the reserve stalls the younger row, never the oldest
    pool.bind(3, 4, row_tokens=8)       # shard 1, youngest
    assert pool.ensure_blocks(2, 11)    # oldest grows to page 2
    assert not pool.ensure_blocks(3, 7)  # younger denied (reserve)
    pool.release(2)
    assert pool.ensure_blocks(3, 7)     # freed blocks return to shard 1


def test_tier_slot_pool_rounds_blocks_to_shards():
    from repro.configs import get_config
    cfg = get_config("gemma3-1b", "smoke")
    # capacity*ppr+1 = 4*4+1 = 17 rounds up to 18 over 2 shards
    pool = TierSlotPool(cfg, 4, 16, block_size=4, data_shards=2)
    assert pool.num_blocks == 18
    stats = pool.memory_stats()
    assert stats["data_shards"] == 2
    assert stats["kv_high_water_blocks_by_shard"] == [0, 0]
    with pytest.raises(ValueError):     # 3 rows cannot split 2 ways
        TierSlotPool(cfg, 3, 16, block_size=4, data_shards=2)
    with pytest.raises(ValueError):     # one request per shard must fit
        TierSlotPool(cfg, 4, 16, block_size=4, num_blocks=6, data_shards=2)


# -- host-sync coalescing (satellite: one device_get per tier per tick) -----


def _one_tier_engine(**kw):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock
    cfg = get_config("gemma3-1b", "smoke")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return CascadeEngine([TierSpec("t", cfg, params)], slots=4,
                         prompt_len=32, gen_len=4, prefill_chunk=8,
                         clock=VirtualClock(), **kw)


def test_mixed_prefill_decode_tick_pays_one_sync():
    """A tick advancing prefill chunks AND a fused decode step must cost
    exactly one blocking host fetch for the tier (the prefill chunk's
    first-token outputs are consumed by the decode launch on device)."""
    eng = _one_tier_engine()
    eng.warmup()
    assert eng.host_syncs == 0          # warmup never blocks on results
    long = np.arange(32, dtype=np.int32) % 7
    short = np.arange(6, dtype=np.int32) % 5
    eng.submit(long)
    eng.step()                          # admit long, chunk 1: no finished
    assert eng.host_syncs == 0          # nothing to emit -> fetch skipped
    eng.submit(short)
    eng.step()                          # short finishes prefill + decodes;
    assert eng.host_syncs == 1          # long mid-prefill: ONE sync
    before = eng.host_syncs
    eng.step()                          # long still prefilling, short
    assert eng.host_syncs == before + 1  # decoding: still one per tick
    eng.run(max_steps=100)
    assert all(len(r.tokens) == 4 for r in eng.requests)


def test_gen_len_one_emits_exactly_one_token():
    """The coalesced tick must not decode a row whose pending prefill
    first-token emit already completes it: gen_len=1 requests end with
    exactly one token, bit-identical to the uniform one-shot oracle."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock
    cfg = get_config("gemma3-1b", "smoke")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = [(np.arange(16) * (i + 3) % 11).astype(np.int32)
               for i in range(3)]

    def run(chunked):
        eng = CascadeEngine(
            [TierSpec("t", cfg, params)], slots=4, prompt_len=16,
            gen_len=1, prefill_chunk=8, use_chunked_prefill=chunked,
            clock=VirtualClock())
        eng.warmup()
        for p in prompts:
            eng.submit(p)
        eng.run(max_steps=100)
        return [r.tokens for r in eng.requests]

    chunked, uniform = run(True), run(False)
    assert all(len(t) == 1 for t in chunked), chunked
    assert chunked == uniform


def test_tick_sync_count_does_not_regress():
    """Regression bound for the whole drain: the chunked engine must
    average at most one host sync per tier per step."""
    eng = _one_tier_engine()
    eng.warmup()
    for i in range(6):
        eng.submit((np.arange(5 + 3 * i) % 11).astype(np.int32))
    eng.run(max_steps=200)
    assert eng.metrics.steps > 0
    assert eng.host_syncs <= eng.metrics.steps


# -- multi-device parity (subprocess, 8 simulated host devices) -------------


def _run(code: str, timeout=540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


_PARITY_PRELUDE = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import CascadeEngine, TierSpec
    from repro.serving.engine import VirtualClock
    from repro.launch.mesh import make_tier_meshes

    assert jax.device_count() == 8, jax.device_count()
    fast = get_config("gemma3-1b", "smoke")
    exp = get_config("phi4-mini-3.8b", "smoke")
    fp = init_params(fast, jax.random.PRNGKey(0), jnp.float32)
    ep = init_params(exp, jax.random.PRNGKey(1), jnp.float32)
    vocab = min(fast.vocab_size, exp.vocab_size)

    def build(meshes, delta, **kw):
        m = [None, None] if meshes is None else meshes
        eng = CascadeEngine(
            [TierSpec("fast", fast, fp, mesh=m[0]),
             TierSpec("exp", exp, ep, mesh=m[1])],
            deltas=[delta], clock=VirtualClock(), **kw)
        eng.warmup()
        return eng

    def drain(eng, prompts):
        for p in prompts:
            eng.submit(np.asarray(p, np.int32), arrival_time=0.0)
        eng.run(max_steps=3000)
        return [(r.rid, tuple(r.tokens), r.tier,
                 tuple(r.seq_conf_by_tier)) for r in eng.requests]

    def check_parity(base, shard):
        assert len(base) == len(shard)
        for a, b in zip(base, shard):
            assert a[0] == b[0]
            assert a[1] == b[1], (a, b)         # bit-identical tokens
            assert a[2] == b[2], (a, b)         # same escalation decisions
            assert np.allclose(a[3], b[3], atol=1e-6)

    def mid_delta(results):
        # a fixed gate threshold splitting tier-0 confidences at the
        # widest gap: maximally robust to ulp-level reduction reordering
        confs = sorted(r[3][0] for r in results)
        gaps = [(confs[i + 1] - confs[i], i) for i in range(len(confs) - 1)]
        _, i = max(gaps)
        return 0.5 * (confs[i] + confs[i + 1])
"""


def test_sharded_parity_uniform_and_lognormal():
    """Per-tier data meshes (disjoint 4-device sets): token streams and
    escalation decisions bit-match the single-device engine for uniform
    and lognormal prompt lengths, with a δ chosen to split traffic."""
    out = _run(_PARITY_PRELUDE + """
    rng = np.random.default_rng(7)
    PLEN, GLEN, N = 16, 4, 10
    uniform = [rng.integers(0, vocab, PLEN) for _ in range(N)]
    lens = np.clip(np.rint(rng.lognormal(np.log(PLEN / 4), 0.8, N)),
                   1, PLEN).astype(int)
    mixed = [rng.integers(0, vocab, L) for L in lens]
    kw = dict(slots=8, prompt_len=PLEN, gen_len=GLEN, prefill_chunk=8)

    # pass 1: learn a splitting delta on the single-device engine
    probe = drain(build(None, 0.5, **kw), uniform)
    delta = mid_delta(probe)

    for prompts in (uniform, mixed):
        meshes = make_tier_meshes([(4, 1), (4, 1)])
        base = drain(build(None, delta, **kw), prompts)
        shard = drain(build(meshes, delta, **kw), prompts)
        check_parity(base, shard)
        tiers = {r[2] for r in base}
        assert tiers == {0, 1}, tiers   # delta really splits traffic
    print("PARITY-OK")
    """)
    assert "PARITY-OK" in out


def test_sharded_parity_oversubscribed_arena():
    """Over-subscribed sharded KV arena: stalls and per-shard reserve
    discipline may reorder work but never change tokens or escalation
    decisions vs the single-device over-subscribed run."""
    out = _run(_PARITY_PRELUDE + """
    rng = np.random.default_rng(11)
    PLEN, GLEN, N = 16, 4, 12
    lens = np.clip(np.rint(rng.lognormal(np.log(PLEN / 4), 0.8, N)),
                   1, PLEN).astype(int)
    prompts = [rng.integers(0, vocab, L) for L in lens]
    # max_seq 20, bs 4 -> 5 pages/row; 8 rows full = 41 blocks; 24
    # over-subscribes (sharded: 6 per shard = one full request + null)
    kw = dict(slots=8, prompt_len=PLEN, gen_len=GLEN, prefill_chunk=8,
              kv_block_size=4, kv_blocks=24)
    meshes = make_tier_meshes([(4, 1), (4, 1)])
    base = drain(build(None, 0.5, **kw), prompts)
    shard = drain(build(meshes, 0.5, **kw), prompts)
    check_parity(base, shard)
    print("PARITY-OK")
    """)
    assert "PARITY-OK" in out


def test_sharded_preemption_replays_bit_identical():
    """Over-subscribed sharded arena WITH a preemption policy: rows
    evicted and replayed on an 8-device run must still produce token
    streams bit-identical to the single-device stall-based run (greedy
    decode replays deterministically), with the same escalation
    decisions — and the overload path must actually fire."""
    out = _run(_PARITY_PRELUDE + """
    rng = np.random.default_rng(11)
    PLEN, GLEN, N = 16, 4, 12
    lens = np.clip(np.rint(rng.lognormal(np.log(PLEN / 4), 0.8, N)),
                   1, PLEN).astype(int)
    prompts = [rng.integers(0, vocab, L) for L in lens]
    # same over-subscribed geometry as the stall parity test: 5
    # pages/row, 24 blocks (6 per shard = one full request + null)
    kw = dict(slots=8, prompt_len=PLEN, gen_len=GLEN, prefill_chunk=8,
              kv_block_size=4, kv_blocks=24)
    base = drain(build(None, 0.5, **kw), prompts)   # stalls, 1 device
    meshes = make_tier_meshes([(4, 1), (4, 1)])
    eng = build(meshes, 0.5, preemption_policy="youngest", **kw)
    shard = drain(eng, prompts)
    check_parity(base, shard)
    s = eng.metrics.summary()
    assert s["preemptions"] > 0, s["preemptions"]
    assert s["replayed_tokens"] > 0
    assert s["completed"] == N and s["conservation"]["ok"]
    print("PREEMPT-PARITY-OK", s["preemptions"], s["replayed_tokens"])
    """)
    assert "PREEMPT-PARITY-OK" in out


def test_sharded_engine_model_axis_and_memory_stats():
    """A tier mesh with a 'model' axis (2x2: tensor-sharded params) runs
    end to end; per-shard KV high-water marks land in memory_stats and
    every request completes.  Model-axis float reductions reassociate, so
    only stream plausibility — not bit-parity — is asserted."""
    out = _run(_PARITY_PRELUDE + """
    rng = np.random.default_rng(3)
    PLEN, GLEN, N = 16, 4, 8
    prompts = [rng.integers(0, vocab, PLEN) for _ in range(N)]
    meshes = make_tier_meshes([(2, 2), (2, 2)])
    eng = build(meshes, 0.5, slots=4, prompt_len=PLEN, gen_len=GLEN,
                prefill_chunk=8)
    res = drain(eng, prompts)
    assert all(len(r[1]) == GLEN for r in res)
    stats = eng.memory_stats()
    for tier in stats:
        assert tier["data_shards"] == 2
        by_shard = tier["kv_high_water_blocks_by_shard"]
        assert len(by_shard) == 2 and sum(by_shard) > 0
        # per-shard maxima may peak at different ticks, so their sum
        # bounds the global concurrent peak from above
        assert sum(by_shard) >= tier["kv_high_water_blocks"]
    topo = eng.mesh_topology()
    assert [t["mesh"] for t in topo] == [{"data": 2, "model": 2}] * 2
    assert topo[0]["device_ids"] == [0, 1, 2, 3]
    assert topo[1]["device_ids"] == [4, 5, 6, 7]
    print("MODEL-AXIS-OK")
    """)
    assert "MODEL-AXIS-OK" in out
