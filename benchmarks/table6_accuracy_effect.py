"""Paper Table 6: does adding L_casc hurt the fast model's own accuracy?
Reports acc(LtC-trained fast) - acc(CE-trained fast) per (fast, exp)."""
import numpy as np

from benchmarks import common


def run(seeds=None):
    seeds = list(seeds or range(common.SEEDS))
    rows = []
    for fast in common.FAST_MODELS:
        for exp in common.EXP_MODELS:
            diffs = []
            for seed in seeds:
                w = common.build_world(seed)
                te = w.data["test"]
                base = (w.logits[(fast, "test")].argmax(-1) == te.y).mean()
                ltc = (w.ltc_logits[(fast, exp, "test")].argmax(-1)
                       == te.y).mean()
                diffs.append((ltc - base) * 100)
            m, se = common.mean_stderr(diffs)
            rows.append({"fast": fast, "exp": exp, "diff": m, "se": se})
    return rows


def main():
    print("table6,fast,exp,acc_diff_pct,se")
    for r in run():
        print(f"acc_effect,{r['fast']},{r['exp']},{r['diff']:+.2f},"
              f"{r['se']:.2f}")


if __name__ == "__main__":
    main()
