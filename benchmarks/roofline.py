"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
prints the per-(arch x shape x mesh) roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio, and memory footprint."""
import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(dryrun_dir=DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main():
    recs = load_records()
    if not recs:
        print("# no dry-run artifacts found; run "
              "PYTHONPATH=src python -m repro.launch.dryrun first")
        return
    print("roofline,arch,shape,mesh,chips,t_compute_ms,t_memory_ms,"
          "t_collective_ms,bottleneck,useful_flops_ratio,temp_gb,note")
    for r in recs:
        print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},{r['chips']},"
              f"{r['t_compute']*1e3:.2f},{r['t_memory']*1e3:.2f},"
              f"{r['t_collective']*1e3:.2f},{r['bottleneck']},"
              f"{r['useful_flops_ratio']:.3f},"
              f"{(r.get('peak_memory_gb') or 0):.1f},"
              f"\"{r.get('note','')}\"")


if __name__ == "__main__":
    main()
