"""Paper Figure 3: model splitting (early-exit backbone, the MSDNet
stand-in) with and without LtC (Eq 6), over several architecture
parameterizations.  Reports the (MACs, Acc) trade-off point at the
best-val δ for each exit-gate configuration."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import cascade, losses
from repro.core import confidence as conf_lib
from repro.models import classifier as clf

# (name, widths, exits) — analogues of the four MSDNet settings in Fig 3
SETTINGS = [
    ("nB2_s2_b4", (64,) * 4, (1,)),
    ("nB5_s1_b4", (64,) * 8, (1, 2, 3, 5)),
    ("nB7_s1_b1", (96,) * 7, (0, 1, 2, 3, 4, 5)),
    ("nB10_s2_b4", (96,) * 10, (1, 3, 5, 7)),
]


def eval_setting(name, widths, exits, seed, ltc_w):
    return common._cache(
        f"fig3_{name}_s{seed}_w{ltc_w}_n{common.NUM_SAMPLES}.pkl",
        lambda: _eval_setting(name, widths, exits, seed, ltc_w))


def _eval_setting(name, widths, exits, seed, ltc_w):
    ds = common.teacher_task(num_samples=common.NUM_SAMPLES, seed=seed)
    tr, va, te = ds.split((0.9, 0.05, 0.05), seed=seed)
    nc = int(tr.y.max()) + 1
    cfg = clf.EarlyExitConfig(name, widths, exits, nc, tr.x.shape[1])
    params = clf.train_early_exit(cfg, jnp.asarray(tr.x), jnp.asarray(tr.y),
                                  key=jax.random.PRNGKey(seed), ltc_w=ltc_w,
                                  epochs=common.EPOCHS, lr=0.03)

    def stats(split):
        chain = clf.early_exit_apply(params, cfg, jnp.asarray(split.x))
        y = jnp.asarray(split.y)
        confs = np.stack([np.asarray(conf_lib.max_prob(c))
                          for c in chain[:-1]])
        corr = np.stack([np.asarray(losses.correct(c, y)) for c in chain])
        return confs, corr

    costs = np.array([cfg.macs_upto(i) for i in range(len(exits) + 1)],
                     np.float32)
    # marginal cost per member (shared backbone: later exits only pay the
    # increment, per the paper's model-splitting cost model)
    marg = np.concatenate([[costs[0]], np.diff(costs)])

    confs_v, corr_v = stats(va)
    # single shared δ swept on val (the paper's per-figure operating curve)
    grid = np.linspace(0, 1, 41)
    deltas = np.repeat(grid[:, None], len(exits), 1)
    out_v = cascade.evaluate_cascade(confs_v, corr_v, marg, deltas)
    i = int(np.argmax(np.asarray(out_v["acc"])
                      - 1e-9 * np.asarray(out_v["cost"])))
    confs_t, corr_t = stats(te)
    out_t = cascade.evaluate_cascade(confs_t, corr_t, marg,
                                     deltas[i:i + 1])
    return float(out_t["acc"][0]) * 100, float(out_t["cost"][0])


def run(seeds=None):
    seeds = list(seeds or range(min(common.SEEDS, 2)))
    rows = []
    for name, widths, exits in SETTINGS:
        for variant, w in (("msdnet", 0.0), ("msdnet_ltc", 1.0)):
            accs, macs = [], []
            for seed in seeds:
                a, c = eval_setting(name, widths, exits, seed, w)
                accs.append(a)
                macs.append(c)
            rows.append({"setting": name, "variant": variant,
                         "acc": common.mean_stderr(accs),
                         "macs": common.mean_stderr(macs)})
    return rows


def main():
    print("fig3,setting,variant,acc_pct,acc_se,macs,macs_se")
    for r in run():
        print(f"splitting,{r['setting']},{r['variant']},"
              f"{r['acc'][0]:.2f},{r['acc'][1]:.2f},"
              f"{r['macs'][0]:.0f},{r['macs'][1]:.0f}")


if __name__ == "__main__":
    main()
