"""Serving throughput benchmark: the async cascade engine under Poisson
traffic, swept over offered load.

Emits one ``BENCH {json}`` line (and a json file) with throughput,
latency percentiles, escalation rate, and Eq 7 cascade-vs-always-expensive
FLOPs per request — the start of the serving perf trajectory.

    PYTHONPATH=src python -m benchmarks.serving_throughput

Scale knobs: REPRO_SERVE_BENCH_{REQUESTS,SLOTS,GEN_LEN} (smoke defaults).
"""
from __future__ import annotations

import json
import os
import time

REQUESTS = int(os.environ.get("REPRO_SERVE_BENCH_REQUESTS", "48"))
SLOTS = int(os.environ.get("REPRO_SERVE_BENCH_SLOTS", "8"))
GEN_LEN = int(os.environ.get("REPRO_SERVE_BENCH_GEN_LEN", "12"))
RATES = (4.0, 16.0)
OUT = os.environ.get("REPRO_SERVE_BENCH_OUT",
                     "experiments/bench/serving_throughput.json")


def main() -> None:
    from repro.launch import serve_async

    points = []
    for rate in RATES:
        args = serve_async.make_parser().parse_args([
            "--requests", str(REQUESTS), "--rate", str(rate),
            "--slots", str(SLOTS), "--gen-len", str(GEN_LEN),
            "--prompt-len", "16",
        ])
        t0 = time.time()
        s = serve_async.run(args)
        points.append({
            "rate": rate,
            "requests": s["requests"],
            "throughput": s["throughput"],
            "latency_p50": s["latency_p50"],
            "latency_p95": s["latency_p95"],
            "ttft_p50": s["ttft_p50"],
            "escalation_rate": s["escalation_rates"][0],
            "tier_utilization": s["tier_utilization"],
            "flops_per_request_cascade": s["flops_per_request_cascade"],
            "flops_per_request_always_expensive":
                s["flops_per_request_always_expensive"],
            "wall_s": time.time() - t0,
        })
        print(f"rate={rate}: throughput {s['throughput']:.2f} req/s, "
              f"p50 {s['latency_p50']:.3f}s, p95 {s['latency_p95']:.3f}s, "
              f"esc {s['escalation_rates'][0]:.3f}", flush=True)

    bench = {
        "bench": "serving_throughput",
        "slots": SLOTS,
        "gen_len": GEN_LEN,
        "points": points,
        "flops_saving_vs_always_expensive": [
            1.0 - p["flops_per_request_cascade"]
            / p["flops_per_request_always_expensive"] for p in points],
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2, default=float)
    print("BENCH " + json.dumps(bench, default=float))


if __name__ == "__main__":
    main()
