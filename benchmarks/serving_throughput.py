"""Serving throughput benchmark: the async cascade engine under Poisson
traffic, swept over offered load and prompt-length distribution.

Emits one ``BENCH {json}`` line (and a json file) with throughput,
latency percentiles, escalation rate, Eq 7 cascade-vs-always-expensive
FLOPs per request, per-tier **launches-per-tick and host_syncs** (the
unified token-batch execution budget: one compiled program and one
``device_get`` per active tier per tick), and — for the mixed-length
workloads served by chunked paged prefill — the live-vs-processed
prefill token ratio (the padding tax the chunked path removes) and
per-prompt-length-bucket TTFT.  A three-way execution-backend sweep
(``step_ab`` in the artifact; ``benchmarks/step_launches.py`` is the
dedicated microbenchmark) re-runs the mixed-length workload at ≥3
offered rates under a fixed δ with the **ragged flat** layout (the
default), the **padded mixed** program (``--no-ragged-step``), and the
legacy **split** path (``--split-step``): stream checksums must match
across all three arms at every rate (bit-identical tokens is a hard
error otherwise), flat must beat both on throughput, and flat's
wasted-slot ratio must sit strictly below padded's.  One point is
additionally re-run as a traced-vs-untraced A/B under a
deterministic virtual clock (``trace_overhead``): the tracer must
leave steps/launches/host_syncs untouched (hard error otherwise);
its host cost — the wall-time delta — is recorded (relative overhead
grew with the ragged layout, whose faster ticks shrink the baseline).
Each sweep point also records the streaming per-gate calibration
telemetry (confidence histograms, reliability bins, ECE).  A final
stall-vs-preempt A/B (``preempt_ab``) re-runs one point on an
over-subscribed KV arena under a deterministic virtual clock with
``--preemption none`` vs ``youngest``: evict-and-replay should improve
tail TTFT over stalling at equal completed work (conservation is a
hard error in both arms).  A prefix-cache A/B (``prefix_ab``) serves a
shared-prefix workload (``--shared-prefix-frac 0.8``, fixed δ,
virtual clock) with refcounted KV prefix sharing on vs off: live
prefill tokens should drop ≥2x at bit-identical stream checksums
(mismatch is a hard error).  A speculative-decoding A/B (``spec_ab``)
serves a self-speculation workload (same model + param seed on both
tiers, δ=1.0 so everything escalates; decode-heavy single-wave
traffic — the regime speculation targets, see the section comment)
with ``--speculate`` at k∈{0,2,4} vs the escalation-only oracle: k≥2
must beat the oracle's output tokens/s with the accept rate recorded,
and ALL arms — including k=0 — must produce bit-identical stream
checksums (hard error otherwise; greedy speculative acceptance emits
scoring-model argmaxes only, so this holds at any k).

    PYTHONPATH=src python -m benchmarks.serving_throughput

Scale knobs: REPRO_SERVE_BENCH_{REQUESTS,SLOTS,GEN_LEN,PROMPT_LEN,
CHUNK,DISTS,TIER_MESH}, plus REPRO_SERVE_BENCH_SECTIONS (comma list
choosing which sections run — CI smokes pick one) and the spec_ab
overrides REPRO_SERVE_BENCH_SPEC_{MODEL,REQUESTS,GEN_LEN}.  The BENCH json
records the
host's device count, each tier's mesh topology, and per-data-shard KV
block high-water marks.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

REQUESTS = int(os.environ.get("REPRO_SERVE_BENCH_REQUESTS", "48"))
SLOTS = int(os.environ.get("REPRO_SERVE_BENCH_SLOTS", "8"))
GEN_LEN = int(os.environ.get("REPRO_SERVE_BENCH_GEN_LEN", "12"))
PROMPT_LEN = int(os.environ.get("REPRO_SERVE_BENCH_PROMPT_LEN", "64"))
CHUNK = int(os.environ.get("REPRO_SERVE_BENCH_CHUNK", "16"))
RATES = (4.0, 16.0)
DISTS = tuple(os.environ.get("REPRO_SERVE_BENCH_DISTS",
                             "uniform,lognormal,bimodal").split(","))
# sharded serving: comma-separated per-tier mesh shapes ("4x1,4x1");
# empty = single device.  Simulated multi-device runs additionally need
# XLA_FLAGS=--xla_force_host_platform_device_count=N in the environment.
TIER_MESH = os.environ.get("REPRO_SERVE_BENCH_TIER_MESH", "")
OUT = os.environ.get("REPRO_SERVE_BENCH_OUT",
                     "experiments/bench/serving_throughput.json")
# comma-separated subset of sections to run (CI smokes pick one section
# instead of the full sweep); default: everything
SECTIONS = frozenset(os.environ.get(
    "REPRO_SERVE_BENCH_SECTIONS",
    "points,step_ab,trace_overhead,preempt_ab,prefix_ab,spec_ab"
).split(","))


def check_open_loop(s: dict) -> None:
    """Open-loop sanity bound: completions can't outpace arrivals, so
    throughput must not exceed the realized offered rate (makespan is at
    least the arrival span).  A violation means the numbers were produced
    by broken timing (e.g. a clock not covering the arrival window)."""
    offered = s.get("offered_rate", float("nan"))
    if offered == offered and s["throughput"] > offered * 1.001:
        raise RuntimeError(
            f"impossible open-loop throughput {s['throughput']:.2f} req/s "
            f"> realized offered rate {offered:.2f} req/s")


def environment() -> dict:
    import platform

    import jax

    return {
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
        "device_count": jax.device_count(),
        "jax": jax.__version__,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
    }


def launch_stats(s: dict) -> dict:
    """The launch-efficiency slice of a summary: compiled-program
    dispatches and blocking device_gets, per tier, absolute and per
    engine tick."""
    return {
        "unified_step": s.get("unified_step"),
        "steps": s["steps"],
        "launches": s["launches"],
        "launches_per_tick": s["launches_per_tick"],
        "host_syncs": s["host_syncs"],
        "host_syncs_per_tick": s["host_syncs_per_tick"],
    }


def main() -> None:
    from repro.launch import serve_async

    def base_argv(dist, rate):
        argv = [
            "--requests", str(REQUESTS), "--rate", str(rate),
            "--slots", str(SLOTS), "--gen-len", str(GEN_LEN),
            "--prompt-len", str(PROMPT_LEN),
            "--length-dist", dist, "--prefill-chunk", str(CHUNK),
        ]
        if TIER_MESH:
            argv += ["--tier-mesh"] + TIER_MESH.split(",")
        return argv

    points = []
    if "points" in SECTIONS:
        for dist in DISTS:
            for rate in RATES:
                args = serve_async.make_parser().parse_args(
                    base_argv(dist, rate))
                t0 = time.time()
                s = serve_async.run(args)
                check_open_loop(s)
                points.append({
                    "rate": rate,
                    "length_dist": dist,
                    "max_prompt_len": PROMPT_LEN,
                    "prompt_len_mean": s["prompt_len_mean"],
                    "prefill_chunk": s["prefill_chunk"],
                    "offered_rate": s["offered_rate"],
                    "requests": s["requests"],
                    "throughput": s["throughput"],
                    "latency_p50": s["latency_p50"],
                    "latency_p95": s["latency_p95"],
                    "ttft_p50": s["ttft_p50"],
                    "ttft_p50_by_prompt_bucket":
                        s["ttft_p50_by_prompt_bucket"],
                    "prefill_live_tokens": s["prefill_live_tokens"],
                    "prefill_processed_tokens": s["prefill_processed_tokens"],
                    "prefill_live_token_ratio": s["prefill_live_token_ratio"],
                    "escalation_rate": s["escalation_rates"][0],
                    "escalation_budget": s["escalation_budget"],
                    "tier_utilization": s["tier_utilization"],
                    "flops_per_request_cascade": s["flops_per_request_cascade"],
                    "flops_per_request_always_expensive":
                        s["flops_per_request_always_expensive"],
                    # mesh topology + per-shard KV high-water (kv_arena
                    # carries kv_high_water_blocks_by_shard per tier)
                    "tier_meshes": s["tier_meshes"],
                    "step_exec": launch_stats(s),
                    "kv_arena": s["kv_arena"],
                    "kv_high_water_bytes_total":
                        sum(t["kv_high_water_bytes"] for t in s["kv_arena"]),
                    "kv_dense_equiv_bytes_total":
                        sum(t["dense_equiv_bytes"] for t in s["kv_arena"]),
                    # streaming gate calibration (conf/esc histograms,
                    # reliability bins, ECE against the escalation-outcome
                    # agreement proxy — see docs/serving.md)
                    "gate_calibration": s["gate_calibration"],
                    "wall_s": time.time() - t0,
                })
                print(f"dist={dist} rate={rate}: "
                      f"throughput {s['throughput']:.2f} req/s "
                      f"(offered {s['offered_rate']:.2f}), "
                      f"p50 {s['latency_p50']:.3f}s, "
                      f"ttft p50 {s['ttft_p50']:.3f}s, "
                      f"live-token ratio {s['prefill_live_token_ratio']:.3f}, "
                      f"esc {s['escalation_rates'][0]:.3f} "
                      f"(budget {s['escalation_budget']})", flush=True)

    # flat-vs-padded-vs-split three-way A/B over offered rates (mixed
    # lengths, fixed δ so the gate is identical across arms): the same
    # deterministic workload, only the execution backend differs.  The
    # split path dispatches chunk_fn AND step_fn on mixed ticks; padded
    # unified launches one [capacity, width] mixed program; the ragged
    # flat layout launches one [1, W] program over just the live tokens.
    # Checksums are a hard error (all three must emit bit-identical
    # token streams); flat must win throughput against both arms and
    # carry strictly less slot padding than the padded program.
    ab_dist = "lognormal" if "lognormal" in DISTS else DISTS[0]
    step_ab = None
    if "step_ab" in SECTIONS:
        ab_rates = (RATES[0], (RATES[0] + RATES[1]) / 2.0, RATES[1])
        ab_arms = (("flat", []), ("padded", ["--no-ragged-step"]),
                   ("split", ["--split-step"]))
        step_ab = {"length_dist": ab_dist, "delta": 0.5,
                   "rates": list(ab_rates), "points": []}
        for rate in ab_rates:
            pt = {"rate": rate}
            for mode, extra in ab_arms:
                args = serve_async.make_parser().parse_args(
                    base_argv(ab_dist, rate) + ["--delta", "0.5"] + extra)
                t0 = time.time()
                s = serve_async.run(args)
                check_open_loop(s)
                pt[mode] = dict(
                    launch_stats(s),
                    ragged_step=s["ragged_step"],
                    throughput=s["throughput"],
                    latency_p50=s["latency_p50"],
                    ttft_p50=s["ttft_p50"],
                    step_live_tokens=s["step_live_tokens"],
                    step_processed_tokens=s["step_processed_tokens"],
                    wasted_slot_ratio=s["wasted_slot_ratio"],
                    mid_run_recompiles=s["mid_run_recompiles"],
                    stream_checksum=s["stream_checksum"],
                    wall_s=time.time() - t0)
                print(f"step A/B [{mode}] rate={rate}: throughput "
                      f"{pt[mode]['throughput']:.2f} req/s, "
                      f"wasted-slot {pt[mode]['wasted_slot_ratio']:.3f}, "
                      f"launches/tick "
                      f"{[round(x, 3) for x in pt[mode]['launches_per_tick']]}",
                      flush=True)
            if len({pt[m]["stream_checksum"] for m, _ in ab_arms}) != 1:
                raise RuntimeError(
                    f"execution backends disagree on token streams at "
                    f"rate {rate}: "
                    + ", ".join(f"{m}={pt[m]['stream_checksum']}"
                                for m, _ in ab_arms))
            pt["checksums_equal"] = True
            if pt["flat"]["wasted_slot_ratio"] \
                    >= pt["padded"]["wasted_slot_ratio"]:
                raise RuntimeError(
                    f"flat wasted-slot ratio {pt['flat']['wasted_slot_ratio']}"
                    f" not below padded "
                    f"{pt['padded']['wasted_slot_ratio']} at rate {rate}")
            pt["flat_wins_throughput"] = (
                pt["flat"]["throughput"] > pt["padded"]["throughput"]
                and pt["flat"]["throughput"] > pt["split"]["throughput"])
            step_ab["points"].append(pt)
        step_ab["flat_wins_all_rates"] = all(
            p["flat_wins_throughput"] for p in step_ab["points"])
        print(f"step A/B: flat wins throughput at "
              f"{sum(p['flat_wins_throughput'] for p in step_ab['points'])}"
              f"/{len(step_ab['points'])} rates, streams bit-identical",
              flush=True)

    # traced-vs-untraced A/B at the same representative point: tracing
    # must be observational.  Both arms run under a VirtualClock so the
    # workload is tick-deterministic — identical steps, launches, and
    # host sync counts are then exact requirements (enforced here and
    # test-asserted in tests/test_observability.py), and the tracer's
    # host cost shows up purely as wall-time overhead.
    trace_overhead = None
    if "trace_overhead" in SECTIONS:
        from repro.serving.engine import VirtualClock

        trace_overhead = {"length_dist": ab_dist, "rate": RATES[0]}
        trace_path = os.path.join(tempfile.gettempdir(),
                                  "serving_throughput_trace.json")
        for arm, extra in (("untraced", []),
                           ("traced", ["--trace-out", trace_path])):
            args = serve_async.make_parser().parse_args(
                base_argv(ab_dist, RATES[0]) + extra)
            t0 = time.time()
            s = serve_async.run(args, VirtualClock())
            rec = dict(launch_stats(s), throughput=s["throughput"],
                       latency_p50=s["latency_p50"],
                       wall_s=time.time() - t0)
            if arm == "traced":
                rec["trace_events"] = s["trace_events"]
                rec["trace_dropped"] = s["trace_dropped"]
            trace_overhead[arm] = rec
        for key in ("steps", "launches", "host_syncs", "host_syncs_per_tick"):
            if trace_overhead["traced"][key] != trace_overhead["untraced"][key]:
                raise RuntimeError(
                    f"tracing changed {key}: "
                    f"{trace_overhead['traced'][key]} traced vs "
                    f"{trace_overhead['untraced'][key]} untraced")
        w_un = trace_overhead["untraced"]["wall_s"]
        w_tr = trace_overhead["traced"]["wall_s"]
        trace_overhead["wall_overhead_pct"] = 100.0 * (w_tr - w_un) / w_un
        print(f"trace A/B: untraced {w_un:.2f}s, traced {w_tr:.2f}s wall "
              f"({trace_overhead['wall_overhead_pct']:+.2f}% overhead, "
              f"{trace_overhead['traced']['trace_events']} events, "
              f"host syncs/launches/steps identical)", flush=True)

    # stall-vs-preempt A/B on an over-subscribed KV arena: same
    # deterministic workload (VirtualClock, fixed seed), arena sized so
    # rows contend for blocks.  `none` absorbs exhaustion by stalling
    # rows in place; `youngest` evicts-and-replays a victim, freeing its
    # blocks for the rows ahead of it — the tail TTFT (a stalled
    # admission queue) is where the policy should pay off, at equal
    # completed work (token streams are bit-identical either way).
    preempt_ab = None
    if "preempt_ab" in SECTIONS:
        over_blocks = max(
            2 * ((PROMPT_LEN + GEN_LEN + 15) // 16) + SLOTS // 2, 8)
        preempt_ab = {"length_dist": ab_dist, "rate": RATES[1],
                      "kv_blocks": over_blocks}
        for arm in ("none", "youngest"):
            args = serve_async.make_parser().parse_args(
                base_argv(ab_dist, RATES[1])
                + ["--kv-blocks", str(over_blocks), "--preemption", arm])
            t0 = time.time()
            s = serve_async.run(args, VirtualClock())
            preempt_ab[arm] = {
                "completed": s["completed"],
                "throughput": s["throughput"],
                "ttft_p50": s["ttft_p50"],
                "ttft_p95": s["ttft_p95"],
                "latency_p95": s["latency_p95"],
                "preemptions": s["preemptions"],
                "replayed_tokens": s["replayed_tokens"],
                "conservation_ok": s["conservation"]["ok"],
                "wall_s": time.time() - t0,
            }
            if not s["conservation"]["ok"]:
                raise RuntimeError(
                    f"preempt A/B [{arm}]: conservation violated "
                    f"{s['conservation']}")
            print(f"preempt A/B [{arm}]: ttft p95 {s['ttft_p95']:.2f}, "
                  f"latency p95 {s['latency_p95']:.2f}, "
                  f"throughput {s['throughput']:.2f} req/tick, "
                  f"preempted {s['preemptions']} "
                  f"(replayed {s['replayed_tokens']} tok)", flush=True)
        preempt_ab["ttft_p95_improvement_pct"] = 100.0 * (
            preempt_ab["none"]["ttft_p95"] - preempt_ab["youngest"]["ttft_p95"]
        ) / preempt_ab["none"]["ttft_p95"]
        print(f"preempt A/B: p95 TTFT "
              f"{preempt_ab['ttft_p95_improvement_pct']:+.1f}% vs stalls",
              flush=True)

    # prefix-cache A/B: the same shared-prefix workload (every prompt's
    # first 80% of tokens come from one base sequence — system-prompt
    # traffic) served with refcounted KV prefix sharing on vs off.
    # Deterministic VirtualClock + fixed δ, so the cache may only change
    # *where prompt KV comes from*, never a token: identical stream
    # checksums are a hard error otherwise.  The headline is live
    # prefill tokens actually computed — cached tokens are admitted
    # straight past prefill — which should drop ≥2x at frac 0.8.
    prefix_ab = None
    if "prefix_ab" in SECTIONS:
        prefix_ab = {"length_dist": "uniform", "rate": RATES[0],
                     "shared_prefix_frac": 0.8, "delta": 0.5}
        for arm, extra in (("off", []), ("on", ["--prefix-cache"])):
            args = serve_async.make_parser().parse_args(
                base_argv("uniform", RATES[0])
                + ["--shared-prefix-frac", "0.8", "--delta", "0.5"] + extra)
            t0 = time.time()
            s = serve_async.run(args, VirtualClock())
            pc = s.get("prefix_cache") or {}
            shared_hw = sum(t.get("kv_shared_high_water_blocks", 0)
                            for t in s["kv_arena"])
            prefix_ab[arm] = {
                "completed": s["completed"],
                "throughput": s["throughput"],
                "ttft_p50": s["ttft_p50"],
                "prefill_live_tokens": s["prefill_live_tokens"],
                "prefill_processed_tokens": s["prefill_processed_tokens"],
                "stream_checksum": s["stream_checksum"],
                "prefix_hit_rate": pc.get("hit_rate"),
                "prefix_cached_tokens": pc.get("cached_tokens"),
                "prefix_cached_token_frac": pc.get("cached_token_frac"),
                "kv_shared_high_water_blocks": shared_hw,
                "wall_s": time.time() - t0,
            }
            print(f"prefix A/B [{arm}]: live prefill tokens "
                  f"{s['prefill_live_tokens']}, ttft p50 {s['ttft_p50']:.2f}"
                  + (f", hit rate {pc['hit_rate']:.2f} "
                     f"(cached {pc['cached_tokens']} tok)"
                     if arm == "on" and pc else ""), flush=True)
        if prefix_ab["on"]["stream_checksum"] \
                != prefix_ab["off"]["stream_checksum"]:
            raise RuntimeError(
                "prefix cache changed token streams: checksum "
                f"{prefix_ab['on']['stream_checksum']} on vs "
                f"{prefix_ab['off']['stream_checksum']} off")
        prefix_ab["prefill_token_reduction"] = (
            prefix_ab["off"]["prefill_live_tokens"]
            / max(prefix_ab["on"]["prefill_live_tokens"], 1))
        print(f"prefix A/B: {prefix_ab['prefill_token_reduction']:.2f}x fewer "
              "live prefill tokens, streams bit-identical", flush=True)

    # speculative cascade decoding A/B (spec_ab): tokens/s vs the
    # escalation-only oracle at a recorded accept rate.  Self-speculation
    # configuration — the SAME model config and param seed on both tiers
    # (--expensive-seed = --seed) under δ=1.0, so every request escalates
    # and re-decodes on the "expensive" tier with the cheap tier's
    # retained row drafting ahead; the tiers agree everywhere, isolating
    # the engine-level effect (multi-token verify ticks) at accept rate
    # ~1.  --spec-delta 0.0 keeps every draft (δ=1.0 would truncate all
    # of them).  The workload is the regime speculation targets —
    # decode-heavy (gen_len 2×GEN_LEN) and a single wave (requests =
    # slots): a draft row occupies a fast-tier slot for its target's
    # whole lifetime, so under heavily queued admission speculation
    # trades away the fast tier's prefill/decode overlap and can LOSE
    # end-to-end (measured: 0.88× at k=2 with 48 requests through 8
    # slots) — that regime is `points`'s job to show, not this arm's
    # (knobs: REPRO_SERVE_BENCH_SPEC_REQUESTS/_SPEC_GEN_LEN).  Four
    # arms under one deterministic VirtualClock workload: no
    # --speculate (baseline oracle), k=0 (speculation machinery on,
    # drafting off — required bit-identical), k=2 and k=4 (must beat
    # the baseline's output tokens/s; any checksum mismatch is a hard
    # error).
    spec_ab = None
    if "spec_ab" in SECTIONS:
        from repro.serving.engine import VirtualClock as _VClock
        spec_model = os.environ.get("REPRO_SERVE_BENCH_SPEC_MODEL",
                                    "gemma3-1b")
        spec_requests = int(os.environ.get(
            "REPRO_SERVE_BENCH_SPEC_REQUESTS", str(SLOTS)))
        spec_gen = int(os.environ.get(
            "REPRO_SERVE_BENCH_SPEC_GEN_LEN", str(2 * GEN_LEN)))
        spec_ab = {"length_dist": ab_dist, "rate": RATES[0], "delta": 1.0,
                   "spec_delta": 0.0, "model": spec_model,
                   "requests": spec_requests, "gen_len": spec_gen,
                   "arms": {}}
        for arm, k in (("baseline", None), ("k0", 0), ("k2", 2),
                       ("k4", 4)):
            extra = ["--requests", str(spec_requests),
                     "--gen-len", str(spec_gen),
                     "--fast", spec_model, "--expensive", spec_model,
                     "--expensive-seed", "0", "--delta", "1.0"]
            if k is not None:
                extra += ["--speculate", str(k)]
                if k:
                    extra += ["--spec-delta", "0.0"]
            args = serve_async.make_parser().parse_args(
                base_argv(ab_dist, RATES[0]) + extra)
            t0 = time.time()
            s = serve_async.run(args, _VClock())
            sp = s["speculation"]
            spec_ab["arms"][arm] = {
                "speculation_k": s["speculation_k"],
                "steps": s["steps"],
                "elapsed_ticks": s["elapsed"],
                # the ROADMAP success metric: output tokens per unit of
                # engine time (virtual ticks here), over the makespan
                "tokens_per_s": (s["completed"] * spec_gen / s["elapsed"]
                                 if s["elapsed"] > 0 else float("nan")),
                "throughput": s["throughput"],
                "completed": s["completed"],
                "launches": s["launches"],
                "accept_rate": sp["accept_rate"],
                "drafted": sp["drafted"],
                "accepted": sp["accepted"],
                "rolled_back": sp["rolled_back"],
                "stream_checksum": s["stream_checksum"],
                "wall_s": time.time() - t0,
            }
            a = spec_ab["arms"][arm]
            print(f"spec A/B [{arm}]: {a['tokens_per_s']:.2f} tok/tick "
                  f"({a['steps']} steps, launches {a['launches']}, "
                  f"accept rate {a['accept_rate']:.2f}, "
                  f"{a['drafted']} drafted)", flush=True)
        if len({a["stream_checksum"]
                for a in spec_ab["arms"].values()}) != 1:
            raise RuntimeError(
                "speculative decoding changed token streams: "
                + ", ".join(f"{m}={a['stream_checksum']}"
                            for m, a in spec_ab["arms"].items()))
        spec_ab["checksums_equal"] = True
        base_tkps = spec_ab["arms"]["baseline"]["tokens_per_s"]
        for arm in ("k2", "k4"):
            if spec_ab["arms"][arm]["tokens_per_s"] <= base_tkps:
                raise RuntimeError(
                    f"speculative arm {arm} did not beat the "
                    f"escalation-only oracle: "
                    f"{spec_ab['arms'][arm]['tokens_per_s']:.3f} vs "
                    f"{base_tkps:.3f} tok/tick")
        spec_ab["speedup"] = {
            arm: spec_ab["arms"][arm]["tokens_per_s"] / base_tkps
            for arm in ("k0", "k2", "k4")}
        print("spec A/B: tokens/tick speedup vs escalation-only "
              + "  ".join(f"{m}={v:.2f}x"
                          for m, v in spec_ab["speedup"].items())
              + ", streams bit-identical", flush=True)

    bench = {
        "bench": "serving_throughput",
        "slots": SLOTS,
        "gen_len": GEN_LEN,
        "max_prompt_len": PROMPT_LEN,
        "prefill_chunk": CHUNK,
        "tier_mesh": TIER_MESH or None,
        "env": environment(),
        "points": points,
        "step_ab": step_ab,
        "trace_overhead": trace_overhead,
        "preempt_ab": preempt_ab,
        "prefix_ab": prefix_ab,
        "spec_ab": spec_ab,
        "flops_saving_vs_always_expensive": [
            1.0 - p["flops_per_request_cascade"]
            / p["flops_per_request_always_expensive"] for p in points],
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2, default=float)
    print("BENCH " + json.dumps(bench, default=float))


if __name__ == "__main__":
    main()
