"""Paper Figure 4: sensitivity of Acc^casc / MACs^casc to the LtC
parameters C and w (mobilenetv2 -> {resnet18, resnet152}).

Expected reproduction of the paper's findings: C anticorrelates with
MACs^casc (bigger claimed cost => fewer escalations) and is uncorrelated
with Acc^casc; w shows no monotone trend."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import cascade, losses, thresholds
from repro.core import confidence as conf_lib
from repro.models import classifier as clf

C_GRID = (0.1, 0.3, 0.5, 0.7, 0.9)
W_GRID = (0.25, 0.5, 1.0, 2.0, 4.0)


def eval_point(exp_name, c, w_coef, seed=0):
    return common._cache(
        f"fig4_{exp_name}_c{c}_w{w_coef}_s{seed}.pkl",
        lambda: _eval_point(exp_name, c, w_coef, seed))


def _eval_point(exp_name, c, w_coef, seed=0):
    wld = common.build_world(seed)
    tr = wld.data["train"]
    fast_cfg = wld.zoo_cfgs["mobilenetv2"]
    exp_tr = jnp.asarray(wld.logits[(exp_name, "train")])
    p = clf.train_classifier(fast_cfg, jnp.asarray(tr.x), jnp.asarray(tr.y),
                             key=jax.random.PRNGKey(seed * 31 + 7),
                             epochs=common.EPOCHS, lr=0.03, batch_size=512,
                             exp_logits=exp_tr, ltc_w=w_coef, cost_c=c)

    costs = [fast_cfg.macs, wld.zoo_cfgs[exp_name].macs]

    def stats(split_name):
        split = wld.data[split_name]
        fl, _ = clf.predict(p, jnp.asarray(split.x))
        y = jnp.asarray(split.y)
        conf = np.asarray(conf_lib.max_prob(fl))
        fc = np.asarray(losses.correct(fl, y))
        ec = np.asarray(losses.correct(
            jnp.asarray(wld.logits[(exp_name, split_name)]), y))
        return conf, fc, ec

    cv, fv, ev = stats("val")
    delta, _, _ = thresholds.best_accuracy_delta(cv, fv, ev, costs)
    ct, ft, et = stats("test")
    acc, macs, _ = cascade.two_element_metrics(
        jnp.asarray(ct), jnp.asarray(ft), jnp.asarray(et),
        costs[0], costs[1], delta)
    return float(acc) * 100, float(macs)


def run(seed=0):
    rows = []
    for exp_name in common.EXP_MODELS:
        for c in C_GRID:
            a, m = eval_point(exp_name, c, 1.0, seed)
            rows.append({"exp": exp_name, "param": "C", "value": c,
                         "acc": a, "macs": m})
        for w in W_GRID:
            a, m = eval_point(exp_name, 0.5, w, seed)
            rows.append({"exp": exp_name, "param": "w", "value": w,
                         "acc": a, "macs": m})
    return rows


def main():
    rows = run()
    print("fig4,exp,param,value,acc_pct,macs")
    for r in rows:
        print(f"params,{r['exp']},{r['param']},{r['value']},"
              f"{r['acc']:.2f},{r['macs']:.0f}")
    # correlation summary (the paper's claim)
    for exp_name in common.EXP_MODELS:
        cs = [(r["value"], r["macs"]) for r in rows
              if r["exp"] == exp_name and r["param"] == "C"]
        corr = np.corrcoef([c for c, _ in cs], [m for _, m in cs])[0, 1]
        print(f"# corr(C, MACs) {exp_name}: {corr:.3f} (paper: negative)")


if __name__ == "__main__":
    main()
