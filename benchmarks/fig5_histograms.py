"""Paper Figure 5: confidence distribution per (fast right/wrong x exp
right/wrong) cell, Baseline vs LtC (mobilenetv2 -> resnet18).

Reports per-cell mean confidence + 10-bin histograms; the paper's claims:
LtC shifts mass toward 1 in all cells, most usefully in 'fast only
correct'; the known negative effect in 'exp only correct' is visible."""
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import losses
from repro.core import confidence as conf_lib

CELLS = ("both_right", "fast_only", "exp_only", "both_wrong")


def run(seed=0, fast="mobilenetv2", exp="resnet18"):
    w = common.build_world(seed)
    y = jnp.asarray(w.data["test"].y)
    ec = np.asarray(losses.correct(jnp.asarray(w.logits[(exp, "test")]), y))
    out = {}
    for method in ("baseline", "ltc"):
        conf, fl = common.conf_for(w, method, fast, exp, "test")
        fc = np.asarray(losses.correct(jnp.asarray(fl), y))
        cells = {
            "both_right": (fc == 1) & (ec == 1),
            "fast_only": (fc == 1) & (ec == 0),
            "exp_only": (fc == 0) & (ec == 1),
            "both_wrong": (fc == 0) & (ec == 0),
        }
        out[method] = {}
        for cell, m in cells.items():
            if m.sum() == 0:
                out[method][cell] = {"n": 0, "mean": float("nan"),
                                     "hist": [0] * 10}
                continue
            h, _ = np.histogram(conf[m], bins=10, range=(0, 1))
            out[method][cell] = {"n": int(m.sum()),
                                 "mean": float(conf[m].mean()),
                                 "hist": h.tolist()}
    return out


def main():
    out = run()
    print("fig5,method,cell,n,mean_conf,hist10")
    for method, cells in out.items():
        for cell in CELLS:
            c = cells[cell]
            print(f"hist,{method},{cell},{c['n']},{c['mean']:.4f},"
                  f"\"{c['hist']}\"")
    # claim check
    b, l = out["baseline"], out["ltc"]
    if l["fast_only"]["n"]:
        print(f"# LtC raises conf in fast_only: "
              f"{l['fast_only']['mean']:.3f} vs baseline "
              f"{b['fast_only']['mean']:.3f}")


if __name__ == "__main__":
    main()
