"""Execution-backend A/B/C: ragged flat token-batch vs padded unified
vs the split chunk+decode path, on an identical mixed-length workload.

The ragged engine (the default) packs a tick's live tokens into one
flat ``[1, W]`` batch at a bucketed width and launches ONE compiled
program per tier per tick (``kernels/ragged_attention.py`` behind
``transformer.ragged_step``) — compute is O(live tokens).  The padded
unified backend (``--no-ragged-step``) launches one mixed
``[capacity, width]`` program, paying for every dead slot; the split
escape hatch (``--split-step``) dispatches the legacy chunk_fn +
step_fn pair — two launches on every mixed tick.  This benchmark runs
all three over the same deterministic workload (virtual clock, same
seed/arrivals/lengths) and reports per-tier launches, host syncs,
live-vs-processed token slots (the wasted-slot ratio), compiled-program
counts, and wall time — and asserts identical token counts plus
bit-identical stream checksums (the parity suite in
tests/test_ragged_step.py proves the same per token; here it guards the
A/B's apples-to-apples-ness).

    PYTHONPATH=src python -m benchmarks.step_launches

Emits one ``BENCH {json}`` line and writes
``experiments/bench/step_launches.json``.  Scale knobs:
REPRO_STEP_BENCH_{REQUESTS,SLOTS,GEN_LEN,PROMPT_LEN,CHUNK,RATE,DIST}.
"""
from __future__ import annotations

import json
import os
import time

REQUESTS = int(os.environ.get("REPRO_STEP_BENCH_REQUESTS", "32"))
SLOTS = int(os.environ.get("REPRO_STEP_BENCH_SLOTS", "8"))
GEN_LEN = int(os.environ.get("REPRO_STEP_BENCH_GEN_LEN", "12"))
PROMPT_LEN = int(os.environ.get("REPRO_STEP_BENCH_PROMPT_LEN", "64"))
CHUNK = int(os.environ.get("REPRO_STEP_BENCH_CHUNK", "16"))
RATE = float(os.environ.get("REPRO_STEP_BENCH_RATE", "8"))
DIST = os.environ.get("REPRO_STEP_BENCH_DIST", "lognormal")
OUT = os.environ.get("REPRO_STEP_BENCH_OUT",
                     "experiments/bench/step_launches.json")

MODES = {"ragged": [], "padded": ["--no-ragged-step"],
         "split": ["--split-step"]}


def run_mode(mode: str) -> dict:
    from repro.launch import serve_async
    from repro.serving.engine import VirtualClock

    argv = [
        "--requests", str(REQUESTS), "--rate", str(RATE),
        "--slots", str(SLOTS), "--gen-len", str(GEN_LEN),
        "--prompt-len", str(PROMPT_LEN), "--prefill-chunk", str(CHUNK),
        "--length-dist", DIST, "--virtual-clock",
    ] + MODES[mode]
    args = serve_async.make_parser().parse_args(argv)
    t0 = time.time()
    s = serve_async.run(args, VirtualClock())
    return {
        "unified_step": s["unified_step"],
        "ragged_step": s["ragged_step"],
        "steps": s["steps"],
        "completed": s["completed"],
        "tokens": int(s["completed"]) * GEN_LEN,
        "launches": s["launches"],
        "launches_total": sum(s["launches"]),
        "launches_per_tick": s["launches_per_tick"],
        "host_syncs": s["host_syncs"],
        "host_syncs_per_tick": s["host_syncs_per_tick"],
        "step_live_tokens": s["step_live_tokens"],
        "step_processed_tokens": s["step_processed_tokens"],
        "wasted_slot_ratio": s["wasted_slot_ratio"],
        "mid_run_recompiles": s["mid_run_recompiles"],
        "compiled_programs": [c["compiled_programs"]
                              for c in s["compiled_programs"]],
        "stream_checksum": s["stream_checksum"],
        "tier_names": s["tier_names"],
        "wall_s": time.time() - t0,
    }


def main() -> None:
    import platform

    import jax

    results = {mode: run_mode(mode) for mode in MODES}
    ragged, padded, split = (results[m] for m in
                             ("ragged", "padded", "split"))
    assert ragged["ragged_step"] and ragged["unified_step"]
    assert padded["unified_step"] and not padded["ragged_step"]
    assert not split["unified_step"]
    # same workload, same per-request decode lengths AND bit-identical
    # streams, or the A/B compares different work
    assert ragged["tokens"] == padded["tokens"] == split["tokens"], results
    assert ragged["stream_checksum"] == padded["stream_checksum"] \
        == split["stream_checksum"], results
    assert ragged["mid_run_recompiles"] == 0, ragged

    for mode, r in results.items():
        print(f"{mode:8s} launches {r['launches']} "
              f"({[round(x, 3) for x in r['launches_per_tick']]}/tick)  "
              f"host-syncs {r['host_syncs']} over {r['steps']} ticks  "
              f"wasted-slot {r['wasted_slot_ratio']:.3f}  "
              f"programs {r['compiled_programs']}, "
              f"{r['wall_s']:.1f}s wall", flush=True)

    bench = {
        "bench": "step_launches",
        "requests": REQUESTS, "slots": SLOTS, "gen_len": GEN_LEN,
        "max_prompt_len": PROMPT_LEN, "prefill_chunk": CHUNK,
        "rate": RATE, "length_dist": DIST,
        "env": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "platform": platform.platform(),
        },
        "ragged": ragged,
        "padded": padded,
        "split": split,
        "launch_reduction": (
            1.0 - ragged["launches_total"] / split["launches_total"]
            if split["launches_total"] else float("nan")),
        "wasted_slot_reduction": (
            padded["wasted_slot_ratio"] - ragged["wasted_slot_ratio"]),
        "streams_bit_identical": True,
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2, default=float)
    print("BENCH " + json.dumps(bench, default=float))


if __name__ == "__main__":
    main()
