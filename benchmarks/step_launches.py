"""Launch-count A/B: unified token-batch execution vs the split
chunk+decode path, on an identical mixed-length serving workload.

The unified engine executes every tick as ONE compiled mixed
prefill+decode program per tier (``kernels/mixed_attention.py`` behind
``transformer.mixed_step``) with one blocking ``device_get``; the split
escape hatch (``--split-step``) dispatches the legacy chunk_fn +
step_fn pair — two launches on every mixed tick.  This benchmark runs
both backends over the same deterministic workload (virtual clock, same
seed/arrivals/lengths) and reports per-tier launches and host syncs,
absolute and per tick, plus wall time — and asserts the two backends
produced identical token counts (the parity suite asserts bit-identical
streams; here we just guard the A/B comparison's apples-to-apples-ness).

    PYTHONPATH=src python -m benchmarks.step_launches

Emits one ``BENCH {json}`` line and writes
``experiments/bench/step_launches.json``.  Scale knobs:
REPRO_STEP_BENCH_{REQUESTS,SLOTS,GEN_LEN,PROMPT_LEN,CHUNK,RATE,DIST}.
"""
from __future__ import annotations

import json
import os
import time

REQUESTS = int(os.environ.get("REPRO_STEP_BENCH_REQUESTS", "32"))
SLOTS = int(os.environ.get("REPRO_STEP_BENCH_SLOTS", "8"))
GEN_LEN = int(os.environ.get("REPRO_STEP_BENCH_GEN_LEN", "12"))
PROMPT_LEN = int(os.environ.get("REPRO_STEP_BENCH_PROMPT_LEN", "64"))
CHUNK = int(os.environ.get("REPRO_STEP_BENCH_CHUNK", "16"))
RATE = float(os.environ.get("REPRO_STEP_BENCH_RATE", "8"))
DIST = os.environ.get("REPRO_STEP_BENCH_DIST", "lognormal")
OUT = os.environ.get("REPRO_STEP_BENCH_OUT",
                     "experiments/bench/step_launches.json")


def run_mode(split: bool) -> dict:
    from repro.launch import serve_async
    from repro.serving.engine import VirtualClock

    argv = [
        "--requests", str(REQUESTS), "--rate", str(RATE),
        "--slots", str(SLOTS), "--gen-len", str(GEN_LEN),
        "--prompt-len", str(PROMPT_LEN), "--prefill-chunk", str(CHUNK),
        "--length-dist", DIST, "--virtual-clock",
    ] + (["--split-step"] if split else [])
    args = serve_async.make_parser().parse_args(argv)
    t0 = time.time()
    s = serve_async.run(args, VirtualClock())
    return {
        "unified_step": s["unified_step"],
        "steps": s["steps"],
        "completed": s["completed"],
        "tokens": int(s["completed"]) * GEN_LEN,
        "launches": s["launches"],
        "launches_total": sum(s["launches"]),
        "launches_per_tick": s["launches_per_tick"],
        "host_syncs": s["host_syncs"],
        "host_syncs_per_tick": s["host_syncs_per_tick"],
        "tier_names": s["tier_names"],
        "wall_s": time.time() - t0,
    }


def main() -> None:
    import platform

    import jax

    unified = run_mode(split=False)
    split = run_mode(split=True)
    assert unified["unified_step"] and not split["unified_step"]
    # same workload, same per-request decode lengths: completed-token
    # counts must agree or the A/B compares different work
    assert unified["tokens"] == split["tokens"], (unified, split)

    for mode, r in (("unified", unified), ("split", split)):
        print(f"{mode:8s} launches {r['launches']} "
              f"({[round(x, 3) for x in r['launches_per_tick']]}/tick)  "
              f"host-syncs {r['host_syncs']} over {r['steps']} ticks, "
              f"{r['wall_s']:.1f}s wall", flush=True)

    bench = {
        "bench": "step_launches",
        "requests": REQUESTS, "slots": SLOTS, "gen_len": GEN_LEN,
        "max_prompt_len": PROMPT_LEN, "prefill_chunk": CHUNK,
        "rate": RATE, "length_dist": DIST,
        "env": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "platform": platform.platform(),
        },
        "unified": unified,
        "split": split,
        "launch_reduction": (
            1.0 - unified["launches_total"] / split["launches_total"]
            if split["launches_total"] else float("nan")),
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2, default=float)
    print("BENCH " + json.dumps(bench, default=float))


if __name__ == "__main__":
    main()
