"""Paper Table 5 (ImageNet analogue): the harder synthetic variant —
more classes, deeper teacher — mobilenetv2 -> resnet152, Baseline vs LtC."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import cascade, losses, thresholds
from repro.core import confidence as conf_lib
from repro.data.synthetic import teacher_task
from repro.models import classifier as clf


def run(seeds=(0, 1)):
    return common._cache(
        f"table5_{'_'.join(map(str, seeds))}_n{common.NUM_SAMPLES}.pkl",
        lambda: _run(seeds))


def _run(seeds=(0, 1)):
    res = {}
    for seed in seeds:
        ds = teacher_task(num_samples=common.NUM_SAMPLES, num_classes=25,
                          dim=16, depth=3, obs_noise=0.3, seed=seed + 50)
        tr, va, te = ds.split((0.9, 0.05, 0.05), seed=seed)
        nc = int(tr.y.max()) + 1
        zoo = clf.zoo(in_dim=tr.x.shape[1], num_classes=nc)
        fast_cfg, exp_cfg = zoo["mobilenetv2"], zoo["resnet152"]

        exp_p = clf.train_classifier(exp_cfg, jnp.asarray(tr.x),
                                     jnp.asarray(tr.y),
                                     key=jax.random.PRNGKey(seed),
                                     epochs=common.EPOCHS, lr=0.03,
                                     batch_size=512)
        exp_out = {n: np.asarray(clf.mlp_apply(exp_p, jnp.asarray(s.x)))
                   for n, s in (("train", tr), ("val", va), ("test", te))}

        for method in ("baseline", "ltc"):
            fp = clf.train_classifier(
                fast_cfg, jnp.asarray(tr.x), jnp.asarray(tr.y),
                key=jax.random.PRNGKey(seed + 7), epochs=common.EPOCHS,
                lr=0.03, batch_size=512,
                exp_logits=jnp.asarray(exp_out["train"])
                if method == "ltc" else None,
                ltc_w=1.0 if method == "ltc" else 0.0)

            costs = [fast_cfg.macs, exp_cfg.macs]

            def stats(name, split):
                fl, _ = clf.predict(fp, jnp.asarray(split.x))
                y = jnp.asarray(split.y)
                return (np.asarray(conf_lib.max_prob(fl)),
                        np.asarray(losses.correct(fl, y)),
                        np.asarray(losses.correct(
                            jnp.asarray(exp_out[name]), y)))

            cv, fv, ev = stats("val", va)
            delta, _, _ = thresholds.best_accuracy_delta(cv, fv, ev, costs)
            ct, ft, et = stats("test", te)
            acc, macs, _ = cascade.two_element_metrics(
                jnp.asarray(ct), jnp.asarray(ft), jnp.asarray(et),
                costs[0], costs[1], delta)
            res.setdefault(method, {"acc": [], "macs": []})
            res[method]["acc"].append(float(acc) * 100)
            res[method]["macs"].append(float(macs))
    return {m: {"acc": common.mean_stderr(v["acc"]),
                "macs": common.mean_stderr(v["macs"])}
            for m, v in res.items()}


def main():
    res = run()
    print("table5,method,acc_pct,acc_se,macs,macs_se")
    for m, v in res.items():
        print(f"hard_task,{m},{v['acc'][0]:.2f},{v['acc'][1]:.2f},"
              f"{v['macs'][0]:.0f},{v['macs'][1]:.0f}")


if __name__ == "__main__":
    main()
