"""Decode attention microbenchmark: dense masked arena vs block-paged.

The PR 1 serving decode attends densely over the whole slot arena
``[rows, max_seq]`` every tick — masked-out positions still cost FLOPs
and HBM reads.  The block-paged decode (``kernels.paged_attention``)
touches only the pages a row has actually filled: O(Σ live tokens).
This benchmark times both at several occupancies (live-token fraction
of the arena) and records the KV bytes each must read.

The paged timing runs the gather-then-attend jnp reference over exactly
the pages the kernel would visit (``pl.when`` skips the rest) — the
Mosaic kernel itself only times meaningfully on TPU; off-TPU its
interpret path is parity-checked here instead and reported as
``kernel_parity_max_err``.

    PYTHONPATH=src python -m benchmarks.decode_attention

Scale knobs: REPRO_DECODE_BENCH_{ROWS,MAX_SEQ,KV,GROUPS,HEAD_DIM,BLOCK,REPS}.
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = int(os.environ.get("REPRO_DECODE_BENCH_ROWS", "16"))
MAX_SEQ = int(os.environ.get("REPRO_DECODE_BENCH_MAX_SEQ", "512"))
KV = int(os.environ.get("REPRO_DECODE_BENCH_KV", "2"))
GROUPS = int(os.environ.get("REPRO_DECODE_BENCH_GROUPS", "4"))
HEAD_DIM = int(os.environ.get("REPRO_DECODE_BENCH_HEAD_DIM", "64"))
BLOCK = int(os.environ.get("REPRO_DECODE_BENCH_BLOCK", "32"))
REPS = int(os.environ.get("REPRO_DECODE_BENCH_REPS", "20"))
OCCUPANCIES = (0.25, 0.5, 1.0)
OUT = os.environ.get("REPRO_DECODE_BENCH_OUT",
                     "experiments/bench/decode_attention.json")
ITEM = 4  # f32 bytes


def _time(fn, *args) -> float:
    """Median wall-clock of a jitted fn (compile excluded), in ms."""
    jax.block_until_ready(fn(*args))        # warmup/compile
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e3)


def main() -> None:
    from repro.kernels import ref
    from repro.kernels.paged_attention import paged_attention
    from repro.models.blocks import _gqa_scores_to_out

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (ROWS, 1, KV, GROUPS, HEAD_DIM), jnp.float32)
    k_dense = jax.random.normal(jax.random.PRNGKey(1),
                                (ROWS, MAX_SEQ, KV, HEAD_DIM), jnp.float32)
    v_dense = jax.random.normal(jax.random.PRNGKey(2),
                                (ROWS, MAX_SEQ, KV, HEAD_DIM), jnp.float32)

    @jax.jit
    def dense(q, k, v, pos):
        idx = jnp.arange(MAX_SEQ)[None, None, None, None, :]
        mask = idx <= pos[:, None, None, None, None]
        return _gqa_scores_to_out(q, k, v, mask)

    @jax.jit
    def paged(q, kp, vp, pt, pos):
        return ref.paged_attention_ref(q[:, 0], kp, vp, pt, pos)

    points = []
    for occ in OCCUPANCIES:
        depth = max(1, int(MAX_SEQ * occ))
        pages = math.ceil(depth / BLOCK)
        pos = jnp.full((ROWS,), depth - 1, jnp.int32)
        # pool holding exactly the live pages (+ null block 0)
        nblocks = ROWS * pages + 1
        pt = jnp.asarray(
            1 + np.arange(ROWS * pages).reshape(ROWS, pages), jnp.int32)
        kp = jax.random.normal(jax.random.PRNGKey(3),
                               (nblocks, BLOCK, KV, HEAD_DIM), jnp.float32)
        vp = jax.random.normal(jax.random.PRNGKey(4),
                               (nblocks, BLOCK, KV, HEAD_DIM), jnp.float32)

        dense_ms = _time(dense, q, k_dense, v_dense, pos)
        paged_ms = _time(paged, q, kp, vp, pt, pos)
        kv_dense = 2 * ROWS * MAX_SEQ * KV * HEAD_DIM * ITEM
        kv_paged = 2 * ROWS * pages * BLOCK * KV * HEAD_DIM * ITEM
        points.append({
            "occupancy": occ,
            "depth": depth,
            "pages_per_row": pages,
            "dense_ms": dense_ms,
            "paged_ms": paged_ms,
            "speedup": dense_ms / paged_ms,
            "kv_bytes_read_dense": kv_dense,
            "kv_bytes_read_paged": kv_paged,
        })
        print(f"occ={occ:.2f} depth={depth}: dense {dense_ms:.2f}ms, "
              f"paged {paged_ms:.2f}ms ({dense_ms/paged_ms:.2f}x), "
              f"KV bytes {kv_dense/1e6:.1f}M -> {kv_paged/1e6:.1f}M",
              flush=True)

    # interpret-mode parity of the actual Pallas kernel (small shape:
    # the interpreter is a correctness artifact, not a perf artifact)
    sp, sb = 4, 8
    nb = 4 * sp + 1
    pt_s = jnp.asarray(1 + np.arange(4 * sp).reshape(4, sp), jnp.int32)
    pos_s = jnp.asarray([5, 11, 23, 30], jnp.int32)
    q_s = jax.random.normal(key, (4, KV, GROUPS, HEAD_DIM), jnp.float32)
    kp_s = jax.random.normal(key, (nb, sb, KV, HEAD_DIM), jnp.float32)
    vp_s = jax.random.normal(key, (nb, sb, KV, HEAD_DIM), jnp.float32)
    got = paged_attention(q_s, kp_s, vp_s, pt_s, pos_s, interpret=True)
    want = ref.paged_attention_ref(q_s, kp_s, vp_s, pt_s, pos_s)
    parity = float(jnp.max(jnp.abs(got - want)))

    import platform
    bench = {
        "bench": "decode_attention",
        "rows": ROWS,
        "max_seq": MAX_SEQ,
        "kv_heads": KV,
        "q_per_kv": GROUPS,
        "head_dim": HEAD_DIM,
        "block_size": BLOCK,
        "paged_impl": "jnp page-gather reference (Mosaic kernel timing "
                      "requires TPU; interpret parity below)",
        "kernel_parity_max_err": parity,
        "env": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "platform": platform.platform(),
            "cpu_count": os.cpu_count(),
        },
        "points": points,
    }
    half = next(p for p in bench["points"] if p["occupancy"] == 0.5)
    if half["paged_ms"] > half["dense_ms"]:
        print(f"WARNING: paged slower than dense at 50% occupancy "
              f"({half['paged_ms']:.2f}ms vs {half['dense_ms']:.2f}ms)")
    assert parity < 1e-4, f"kernel/interpret parity broke: {parity}"
    if os.path.dirname(OUT):
        os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2, default=float)
    print("BENCH " + json.dumps(bench, default=float))


if __name__ == "__main__":
    main()
