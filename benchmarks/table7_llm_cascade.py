"""Beyond-paper artifact: the LtC recipe applied to an *LLM* cascade
(reduced gemma3-family fast member, phi4-family expensive member) on the
synthetic bigram/trigram corpus.

Mirrors the paper's protocol at token level: 'correct' = top-1 next-token
match; conf = max softmax prob per token; δ swept on a validation split;
Acc^casc (Eq 2) and FLOPs^casc (Eq 7, FLOPs-per-token in place of MACs)
reported for Baseline vs LtC training of the fast member.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import cascade, thresholds
from repro.core import confidence as conf_lib
from repro.data import bigram_lm
from repro.launch import steps as steps_lib
from repro.launch.train import run as train_run
from repro.models import init_params, transformer

STEPS_FAST = 250
STEPS_EXP = 800       # training budget IS the capacity gap at smoke scale
BATCH = 8
SEQ = 64
VOCAB = 64            # learnable within the step budget (branching 2)


def _token_stats(cfg, params, tokens):
    logits, _ = transformer.train_logits(params, cfg, {"tokens": tokens})
    labels = tokens[:, 1:]
    lg = logits[:, :-1]
    conf = np.asarray(conf_lib.max_prob(lg)).reshape(-1)
    correct = np.asarray((jnp.argmax(lg, -1) == labels)).astype(
        np.float32).reshape(-1)
    return conf, correct


def run(seed=0):
    return common._cache(f"table7_llm_s{seed}.pkl", lambda: _run(seed))


def _run(seed=0):
    fast_cfg = get_config("gemma3-1b", "smoke")
    exp_cfg = get_config("phi4-mini-3.8b", "smoke")

    # 1) train the expensive member (3x budget), then the fast one twice
    exp_params = train_run("phi4-mini-3.8b", variant="smoke",
                           steps=STEPS_EXP, batch=BATCH, seq=SEQ, lr=1e-2,
                           seed=seed, log_every=0, data_seed=seed,
                           vocab=VOCAB)
    fast_base = train_run("gemma3-1b", variant="smoke", steps=STEPS_FAST,
                          batch=BATCH, seq=SEQ, lr=1e-2, seed=seed + 1,
                          log_every=0, data_seed=seed, vocab=VOCAB)
    fast_ltc = train_run("gemma3-1b", variant="smoke", steps=STEPS_FAST,
                         batch=BATCH, seq=SEQ, lr=1e-2, seed=seed + 1,
                         expensive="phi4-mini-3.8b", exp_params=exp_params,
                         ltc_w=1.0, cost_c=0.5, log_every=0, data_seed=seed,
                         vocab=VOCAB)

    # 2) held-out val/test: new sequences from the SAME process
    val = jnp.asarray(bigram_lm(num_seqs=48, seq_len=SEQ, vocab=VOCAB,
                                seed=seed + 1000, table_seed=seed))
    test = jnp.asarray(bigram_lm(num_seqs=64, seq_len=SEQ, vocab=VOCAB,
                                 seed=seed + 2000, table_seed=seed))

    flops_fast = 2.0 * fast_cfg.active_param_count()
    flops_exp = 2.0 * exp_cfg.active_param_count()
    out = {}
    for name, fp in (("baseline", fast_base), ("ltc", fast_ltc)):
        cv, fv = _token_stats(fast_cfg, fp, val)
        _, ev = _token_stats(exp_cfg, exp_params, val)
        delta, _, _ = thresholds.best_accuracy_delta(
            cv, fv, ev, [flops_fast, flops_exp])
        ct, ft = _token_stats(fast_cfg, fp, test)
        _, et = _token_stats(exp_cfg, exp_params, test)
        acc, cost, n_exp = cascade.two_element_metrics(
            jnp.asarray(ct), jnp.asarray(ft), jnp.asarray(et),
            flops_fast, flops_exp, delta)
        out[name] = {"acc": float(acc) * 100, "flops_per_tok": float(cost),
                     "delta": delta, "esc_rate": float(n_exp) / len(ft),
                     "acc_exp": float(et.mean()) * 100}
    return out


def main():
    res = run()
    print("table7_llm,method,token_acc_pct,flops_per_tok,delta,esc_rate,"
          "exp_alone_acc")
    for m, v in res.items():
        print(f"llm_cascade,{m},{v['acc']:.2f},{v['flops_per_tok']:.3e},"
              f"{v['delta']:.2f},{v['esc_rate']:.2f},{v['acc_exp']:.2f}")


if __name__ == "__main__":
    main()
