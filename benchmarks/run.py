"""Benchmark driver: one module per paper table/figure, CSV to stdout.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table23    # one artifact

Module list mirrors the paper (see DESIGN.md §7).  The classifier zoo is
trained once per seed and cached under experiments/bench_cache (delete to
retrain).  Scale knobs: REPRO_BENCH_{SEEDS,EPOCHS,SAMPLES}.
"""
import sys
import time
import traceback

from benchmarks import (decode_attention, fig3_splitting, fig4_params,
                        fig5_histograms, roofline, serving_throughput,
                        step_launches, table1_models, table23_cascade,
                        table4_three_element, table5_hard_task,
                        table6_accuracy_effect, table7_llm_cascade)

ARTIFACTS = {
    "table1": table1_models.main,
    "table23": table23_cascade.main,
    "table4": table4_three_element.main,
    "table5": table5_hard_task.main,
    "table6": table6_accuracy_effect.main,
    "table7_llm": table7_llm_cascade.main,
    "fig3": fig3_splitting.main,
    "fig4": fig4_params.main,
    "fig5": fig5_histograms.main,
    "roofline": roofline.main,
    "serving": serving_throughput.main,
    "decode_attn": decode_attention.main,
    "step_launches": step_launches.main,
}


def main() -> None:
    names = sys.argv[1:] or list(ARTIFACTS)
    failures = []
    for name in names:
        print(f"\n# ===== {name} =====", flush=True)
        t0 = time.time()
        try:
            ARTIFACTS[name]()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
