"""Paper Table 4: three-element cascade (mobilenetv2 -> resnet18 ->
resnet152), Baseline vs LtC (Eq 5 training order)."""
import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core import cascade, losses, thresholds
from repro.core import confidence as conf_lib


def _three_el(w, method):
    """members: mobilenetv2, resnet18, resnet152."""
    fast, mid, exp = "mobilenetv2", "resnet18", "resnet152"
    costs = np.array([w.zoo_cfgs[m].macs for m in (fast, mid, exp)],
                     np.float32)

    def logits_of(member, prev_exp, split):
        if method == "ltc" and member in (fast, mid):
            return w.ltc_logits[(member, prev_exp, split)] if \
                (member, prev_exp, split) in w.ltc_logits else \
                w.logits[(member, split)]
        return w.logits[(member, split)]

    def stats(split):
        y = jnp.asarray(w.data[split].y)
        lf = logits_of(fast, mid if method == "ltc" else None, split)
        lm = logits_of(mid, exp if method == "ltc" else None, split)
        le = w.logits[(exp, split)]
        confs = np.stack([
            np.asarray(conf_lib.max_prob(jnp.asarray(lf))),
            np.asarray(conf_lib.max_prob(jnp.asarray(lm)))])
        corrects = np.stack([
            np.asarray(losses.correct(jnp.asarray(l), y))
            for l in (lf, lm, le)])
        return confs, corrects

    # δ search on val: grid over both gates (coarse, as the paper sweeps)
    confs_v, corr_v = stats("val")
    grid = np.linspace(0, 1, 21)
    best = None
    for d1 in grid:
        out = cascade.evaluate_cascade(
            confs_v, corr_v, costs,
            np.stack([np.full_like(grid, d1), grid], 1))
        accs = np.asarray(out["acc"])
        cost = np.asarray(out["cost"])
        for i in range(len(grid)):
            key = (round(float(accs[i]), 6), -float(cost[i]))
            if best is None or key > best[0]:
                best = (key, (d1, grid[i]))
    deltas = np.array([best[1]])

    confs_t, corr_t = stats("test")
    out = cascade.evaluate_cascade(confs_t, corr_t, costs, deltas)
    return float(out["acc"][0]) * 100, float(out["cost"][0])


def run(seeds=None):
    seeds = list(seeds or range(common.SEEDS))
    res = {}
    for method in ("baseline", "ltc"):
        accs, macs = [], []
        for seed in seeds:
            w = common.build_world(seed)
            a, c = _three_el(w, method)
            accs.append(a)
            macs.append(c)
        res[method] = {"acc": common.mean_stderr(accs),
                       "macs": common.mean_stderr(macs)}
    return res


def main():
    res = run()
    print("table4,method,acc_pct,acc_se,macs,macs_se")
    for m, v in res.items():
        print(f"three_element,{m},{v['acc'][0]:.2f},{v['acc'][1]:.2f},"
              f"{v['macs'][0]:.0f},{v['macs'][1]:.0f}")


if __name__ == "__main__":
    main()
