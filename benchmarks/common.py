"""Shared benchmark harness: train the classifier zoo once, cache
predictions, and provide the five comparison methods of the paper
(Baseline / IDK / ConfNet / Temp. Scaling / LtC).

All benchmarks run on the synthetic teacher task (DESIGN.md §6) with the
paper's protocol: train/val/test split, δ chosen on val by best cascade
accuracy, metrics reported on test over `n_seeds` seeds (mean ± stderr).
"""
from __future__ import annotations

import functools
import os
import pickle
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibration, cascade, losses, thresholds
from repro.core import confidence as conf_lib
from repro.data.synthetic import teacher_task
from repro.models import classifier as clf

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "experiments/bench_cache")
EPOCHS = int(os.environ.get("REPRO_BENCH_EPOCHS", "6"))
NUM_SAMPLES = int(os.environ.get("REPRO_BENCH_SAMPLES", "200000"))
SEEDS = int(os.environ.get("REPRO_BENCH_SEEDS", "3"))

FAST_MODELS = ("alexnet", "vgg11", "mobilenetv2")
EXP_MODELS = ("resnet18", "resnet152")
METHODS = ("baseline", "idk", "confnet", "temp_scaling", "ltc")


def _cache(path, fn):
    os.makedirs(CACHE_DIR, exist_ok=True)
    full = os.path.join(CACHE_DIR, path)
    if os.path.exists(full):
        with open(full, "rb") as f:
            return pickle.load(f)
    out = fn()
    with open(full, "wb") as f:
        pickle.dump(out, f)
    return out


@dataclass
class World:
    """One seed's data + trained zoo + cached predictions."""
    seed: int
    data: dict          # split -> Dataset
    zoo_cfgs: dict
    logits: dict        # (model, split) -> np.ndarray
    feats: dict         # (model, split) -> np.ndarray (penultimate)
    ltc_logits: dict    # (fast, exp, split) -> np.ndarray
    heads: dict         # (fast, kind) -> ConfHead params (np tree)


def _train_and_predict(cfg, tr, splits, key, **kw):
    p = clf.train_classifier(cfg, jnp.asarray(tr.x), jnp.asarray(tr.y),
                             key=key, epochs=EPOCHS, lr=0.03,
                             batch_size=512, **kw)
    out_l, out_f = {}, {}
    for name, split in splits.items():
        logits, feats = clf.mlp_apply(p, jnp.asarray(split.x),
                                      with_features=True)
        out_l[name] = np.asarray(logits)
        out_f[name] = np.asarray(feats)
    return p, out_l, out_f


def build_world(seed: int, verbose: bool = True) -> World:
    def make():
        t0 = time.time()
        ds = teacher_task(num_samples=NUM_SAMPLES, seed=seed)
        tr, va, te = ds.split((0.9, 0.05, 0.05), seed=seed)
        splits = {"train": tr, "val": va, "test": te}
        zoo_cfgs = clf.zoo(in_dim=tr.x.shape[1], num_classes=int(tr.y.max()) + 1)
        logits, feats, params = {}, {}, {}
        for name, cfg in zoo_cfgs.items():
            key = jax.random.PRNGKey(seed * 100 + hash(name) % 97)
            p, ls, fs = _train_and_predict(cfg, tr, splits, key)
            params[name] = p
            for s in splits:
                logits[(name, s)] = ls[s]
                feats[(name, s)] = fs[s]
            if verbose:
                acc = (ls["test"].argmax(-1) == te.y).mean()
                print(f"  [seed {seed}] {name}: test acc {acc*100:.2f}% "
                      f"({time.time()-t0:.0f}s)", flush=True)

        # LtC retrainings: fast model per expensive model (Eq 5 order).
        # The extra (resnet18 -> resnet152) pair supports the Table-4
        # three-element cascade (mobilenet -> r18 -> r152).
        pairs = [(fast, exp) for exp in EXP_MODELS for fast in FAST_MODELS]
        pairs.append(("resnet18", "resnet152"))
        ltc_logits = {}
        for fast, exp in pairs:
            exp_tr = jnp.asarray(logits[(exp, "train")])
            key = jax.random.PRNGKey(seed * 1000 + hash(fast + exp) % 97)
            p, ls, _ = _train_and_predict(
                zoo_cfgs[fast], tr, splits, key,
                exp_logits=exp_tr, ltc_w=1.0, cost_c=0.5)
            for s in splits:
                ltc_logits[(fast, exp, s)] = ls[s]
            if verbose:
                acc = (ls["test"].argmax(-1) == te.y).mean()
                print(f"  [seed {seed}] LtC {fast}|{exp}: "
                      f"test acc {acc*100:.2f}%", flush=True)

        # auxiliary heads (ConfNet / IDK), post-hoc on val features
        heads = {}
        for fast in FAST_MODELS:
            for kind in ("confnet", "idk"):
                key = jax.random.PRNGKey(seed * 7 + hash(fast + kind) % 97)
                head = calibration.fit_conf_head(
                    key, jnp.asarray(feats[(fast, "train")]),
                    jnp.asarray(logits[(fast, "train")]),
                    jnp.asarray(tr.y), kind=kind, steps=400)
                heads[(fast, kind)] = jax.tree.map(np.asarray, head)

        return World(seed=seed, data={"train": tr, "val": va, "test": te},
                     zoo_cfgs=zoo_cfgs, logits=logits, feats=feats,
                     ltc_logits=ltc_logits, heads=heads)

    return _cache(f"world_s{seed}_n{NUM_SAMPLES}_e{EPOCHS}.pkl", make)


def conf_for(world: World, method: str, fast: str, exp: str, split: str):
    """Confidence scores of `fast` under a method (paper §5 baselines)."""
    y = world.data[split].y
    if method == "ltc":
        fl = world.ltc_logits[(fast, exp, split)]
        return np.asarray(conf_lib.max_prob(jnp.asarray(fl))), fl
    fl = world.logits[(fast, split)]
    if method == "baseline":
        return np.asarray(conf_lib.max_prob(jnp.asarray(fl))), fl
    if method == "temp_scaling":
        t = calibration.fit_temperature(
            jnp.asarray(world.logits[(fast, "val")]),
            jnp.asarray(world.data["val"].y), steps=200)
        return np.asarray(conf_lib.max_prob(jnp.asarray(fl), t)), fl
    if method in ("confnet", "idk"):
        head = calibration.ConfHead(*[jnp.asarray(a) for a in
                                      world.heads[(fast, method)]])
        c = calibration.conf_head_apply(head,
                                        jnp.asarray(world.feats[(fast, split)]))
        return np.asarray(c), fl
    raise ValueError(method)


def cascade_eval(world: World, method: str, fast: str, exp: str):
    """Paper protocol: δ from val (best cascade accuracy), report test
    Acc^casc (Eq 2) and MACs^casc (Eq 7)."""
    costs = [world.zoo_cfgs[fast].macs, world.zoo_cfgs[exp].macs]

    def cc(split):
        conf, fl = conf_for(world, method, fast, exp, split)
        y = jnp.asarray(world.data[split].y)
        fc = np.asarray(losses.correct(jnp.asarray(fl), y))
        ec = np.asarray(losses.correct(
            jnp.asarray(world.logits[(exp, split)]), y))
        return conf, fc, ec

    conf_v, fc_v, ec_v = cc("val")
    delta, _, _ = thresholds.best_accuracy_delta(conf_v, fc_v, ec_v, costs)
    conf_t, fc_t, ec_t = cc("test")
    acc, cost, n_exp = cascade.two_element_metrics(
        jnp.asarray(conf_t), jnp.asarray(fc_t), jnp.asarray(ec_t),
        costs[0], costs[1], delta)
    return {"acc": float(acc), "macs": float(cost), "delta": float(delta),
            "n_exp": float(n_exp), "n": len(fc_t)}


def mean_stderr(vals):
    a = np.asarray(vals, np.float64)
    return float(a.mean()), float(a.std(ddof=1) / np.sqrt(len(a))) if len(a) > 1 else 0.0
