"""Paper Tables 2+3: cascade accuracy (Eq 2) and MACs (Eq 7) for every
(fast x expensive) pair under the five methods."""
import numpy as np

from benchmarks import common


def run(seeds=None):
    seeds = list(seeds or range(common.SEEDS))
    rows = []
    for fast in common.FAST_MODELS:
        for exp in common.EXP_MODELS:
            per_method = {}
            for method in common.METHODS:
                accs, macs = [], []
                for seed in seeds:
                    w = common.build_world(seed)
                    r = common.cascade_eval(w, method, fast, exp)
                    accs.append(r["acc"] * 100)
                    macs.append(r["macs"])
                per_method[method] = {
                    "acc": common.mean_stderr(accs),
                    "macs": common.mean_stderr(macs),
                }
            rows.append({"fast": fast, "exp": exp, "methods": per_method})
    return rows


def main():
    rows = run()
    print("table23,fast,exp,method,acc_pct,acc_se,macs,macs_se")
    for r in rows:
        for m, v in r["methods"].items():
            print(f"cascade,{r['fast']},{r['exp']},{m},"
                  f"{v['acc'][0]:.2f},{v['acc'][1]:.2f},"
                  f"{v['macs'][0]:.0f},{v['macs'][1]:.0f}")
    # paper claim check: LtC achieves lowest MACs in most pairs
    wins = 0
    for r in rows:
        best = min(r["methods"], key=lambda m: r["methods"][m]["macs"][0])
        wins += best == "ltc"
    print(f"# LtC lowest-MACs pairs: {wins}/{len(rows)}")


if __name__ == "__main__":
    main()
