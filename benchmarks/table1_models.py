"""Paper Table 1: accuracy and MACs of each zoo member."""
import numpy as np

from benchmarks import common


def run(seeds=None):
    seeds = seeds or range(common.SEEDS)
    rows = {}
    for seed in seeds:
        w = common.build_world(seed)
        te = w.data["test"]
        for name, cfg in w.zoo_cfgs.items():
            acc = (w.logits[(name, "test")].argmax(-1) == te.y).mean()
            rows.setdefault(name, {"macs": cfg.macs, "accs": []})
            rows[name]["accs"].append(acc * 100)
    out = []
    for name, r in rows.items():
        m, se = common.mean_stderr(r["accs"])
        out.append({"model": name, "acc_mean": m, "acc_stderr": se,
                    "macs": r["macs"]})
    return out


def main():
    print("table1_model,acc_pct,stderr,macs")
    for r in run():
        print(f"{r['model']},{r['acc_mean']:.2f},{r['acc_stderr']:.2f},"
              f"{r['macs']}")


if __name__ == "__main__":
    main()
