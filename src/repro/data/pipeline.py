"""Batching + device placement.

``Batches`` is a light epoch-shuffling iterator.  ``shard_batch`` places a
host batch onto a mesh with the canonical batch sharding (('pod','data')
when present), which is all the input pipeline needs to feed pjit."""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec


class Batches:
    def __init__(self, arrays: dict, batch_size: int, *, shuffle: bool = True,
                 seed: int = 0, drop_last: bool = True):
        self.arrays = arrays
        n = next(iter(arrays.values())).shape[0]
        assert all(a.shape[0] == n for a in arrays.values())
        self.n = n
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.drop_last = drop_last
        self.rng = np.random.default_rng(seed)

    def epoch(self) -> Iterator[dict]:
        idx = self.rng.permutation(self.n) if self.shuffle else np.arange(self.n)
        stop = self.n - self.batch_size + 1 if self.drop_last else self.n
        for s in range(0, stop, self.batch_size):
            sl = idx[s:s + self.batch_size]
            yield {k: v[sl] for k, v in self.arrays.items()}

    def __iter__(self):
        while True:
            yield from self.epoch()


def batch_pspec(mesh, ndim: int) -> PartitionSpec:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return PartitionSpec(lead, *([None] * (ndim - 1)))


def shard_batch(batch: dict, mesh) -> dict:
    return {k: jax.device_put(v, NamedSharding(mesh, batch_pspec(mesh, v.ndim)))
            for k, v in batch.items()}
