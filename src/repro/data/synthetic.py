"""Synthetic datasets (the offline-container stand-ins for CIFAR-100 /
ImageNet — see DESIGN.md §6).

Classification: a Gaussian-mixture task whose difficulty is controlled by
class overlap (``noise``) plus a fraction of inherently ambiguous samples
(``hard_frac`` drawn between two classes).  Calibration-relevant structure
matters here: the task must contain samples a small model gets wrong but a
big model gets right, *and* samples both get wrong — otherwise the LtC
loss's distinguishing term (1[exp wrong]) is inert.

Language modeling: a sparse random bigram/trigram process over a vocab —
fast models capture bigram mass, bigger models also capture the trigram
exceptions, recreating the same fast-wrong/expensive-right structure for
the LLM cascade experiments.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Dataset(NamedTuple):
    x: np.ndarray
    y: np.ndarray

    def split(self, fracs=(0.8, 0.1, 0.1), seed: int = 0):
        """train/val/test split (paper: 9:1 train/val + test)."""
        n = self.x.shape[0]
        rng = np.random.default_rng(seed)
        idx = rng.permutation(n)
        out = []
        start = 0
        for f in fracs:
            m = int(round(f * n))
            sl = idx[start:start + m]
            out.append(Dataset(self.x[sl], self.y[sl]))
            start += m
        return out


def gaussian_mixture(num_samples: int = 20000, num_classes: int = 20,
                     dim: int = 64, noise: float = 1.6,
                     hard_frac: float = 0.25, seed: int = 0) -> Dataset:
    """Class centers on a random simplex-ish arrangement; `hard_frac` of
    samples are drawn from midpoints of class pairs (ambiguous)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(num_classes, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    centers *= 3.0
    y = rng.integers(0, num_classes, size=num_samples)
    x = centers[y] + noise * rng.normal(size=(num_samples, dim)).astype(np.float32)
    n_hard = int(hard_frac * num_samples)
    if n_hard:
        j = rng.integers(0, num_classes, size=n_hard)
        mid = 0.5 * (centers[y[:n_hard]] + centers[j])
        x[:n_hard] = mid + noise * rng.normal(size=(n_hard, dim)).astype(np.float32)
    return Dataset(x.astype(np.float32), y.astype(np.int32))


def teacher_task(num_samples: int = 200000, num_classes: int = 10,
                 latent_dim: int = 16, dim: int = 12, depth: int = 2,
                 obs_noise: float = 0.25, boundary_frac: float = 0.35,
                 seed: int = 0, return_info: bool = False):
    """Labels from a fixed random *deep* teacher network applied to the
    observed features — the decision boundary is genuinely nonlinear, so
    student capacity/depth buys accuracy (recreating the paper's Table-1
    ordering: ResNet152 > ResNet18 > compact models).

    `boundary_frac` of samples are rejection-sampled near the teacher's
    decision boundary (small top-2 margin) and labels are
    temperature-sampled: the low-margin pool carries irreducible label
    noise — samples where the fast model errs and part of which the
    expensive model also gets wrong, exactly the structure the LtC loss
    exploits (paper Fig 5).  latent_dim is unused in this observed-space
    variant (kept for config stability).
    """
    rng = np.random.default_rng(seed)
    sizes = [dim] + [96] * depth + [num_classes]
    ws = [rng.normal(size=(a, b)).astype(np.float32) * np.sqrt(2.0 / a)
          for a, b in zip(sizes[:-1], sizes[1:])]

    def teacher(x):
        h = x
        for w in ws[:-1]:
            h = np.tanh(h @ w)
        return h @ ws[-1]

    # oversample, keep a boundary_frac pool of low-margin samples
    x = rng.normal(size=(num_samples * 3, dim)).astype(np.float32)
    lg = teacher(x)
    srt = np.sort(lg, axis=-1)
    margin = srt[:, -1] - srt[:, -2]
    order = np.argsort(margin)
    n_hard = int(boundary_frac * num_samples)
    idx = np.concatenate([order[:n_hard], order[n_hard:num_samples]])
    x, lg = x[idx], lg[idx]
    # temperature-sampled labels: low-margin samples carry irreducible
    # label noise (the 'both models wrong' pool); obs_noise here acts as
    # the sampling temperature.  Teacher logits are normalized so the
    # temperature is meaningful across seeds.
    lg = lg / np.std(lg) * 4.0
    tau = max(obs_noise, 1e-3)
    g = rng.gumbel(size=lg.shape).astype(np.float32)
    y = (lg / tau + g).argmax(-1)
    perm = rng.permutation(len(x))
    ds = Dataset(x[perm].astype(np.float32), y[perm].astype(np.int32))
    if return_info:
        p = np.exp(lg / tau - (lg / tau).max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        info = {"bayes_acc": float(p.max(-1).mean())}
        return ds, info
    return ds


def bigram_lm(num_seqs: int = 2000, seq_len: int = 128, vocab: int = 256,
              branching: int = 4, trigram_frac: float = 0.3,
              seed: int = 0, table_seed=None) -> np.ndarray:
    """Token sequences from a sparse bigram table with trigram 'exceptions'.

    Each token has `branching` plausible successors (uniform).  With
    probability `trigram_frac`, the successor is instead determined by the
    previous *two* tokens — structure only a higher-capacity model captures.
    Returns int32 [num_seqs, seq_len].
    """
    # transition tables come from table_seed so held-out splits can sample
    # NEW sequences from the SAME process (table_seed fixed, seed varied)
    trng = np.random.default_rng(seed if table_seed is None else table_seed)
    bigram = trng.integers(0, vocab, size=(vocab, branching))
    trigram = trng.integers(0, vocab, size=(vocab, vocab))
    rng = np.random.default_rng(seed)
    out = np.empty((num_seqs, seq_len), np.int32)
    tok = rng.integers(0, vocab, size=num_seqs)
    prev = rng.integers(0, vocab, size=num_seqs)
    for t in range(seq_len):
        out[:, t] = tok
        use_tri = rng.random(num_seqs) < trigram_frac
        nxt_bi = bigram[tok, rng.integers(0, branching, size=num_seqs)]
        nxt_tri = trigram[prev, tok]
        nxt = np.where(use_tri, nxt_tri, nxt_bi)
        prev, tok = tok, nxt.astype(np.int64)
    return out
