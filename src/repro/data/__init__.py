from repro.data.pipeline import Batches, batch_pspec, shard_batch
from repro.data.synthetic import Dataset, bigram_lm, gaussian_mixture

__all__ = ["Batches", "batch_pspec", "shard_batch", "Dataset", "bigram_lm",
           "gaussian_mixture"]
