"""Unified mixed prefill+decode attention (Pallas TPU kernel).

The serving engine's **unified token-batch** execution path: one program
per tier per tick serves every live row, whatever it is doing.  Each
batch row contributes a width-``C`` token slice of the tick's work —

  * a **prefill** row's next prompt chunk (``q_len = C`` or the shorter
    final-chunk tail),
  * a **decode** row's single new token (``q_len = 1``),
  * a **stalled / idle** row nothing at all (``q_len = 0``: skipped,
    output zeroed).

All rows share the block-paged KV pool layout of
:mod:`repro.kernels.paged_attention` (``[num_blocks, block_size, KV,
hd]``); row ``b``'s query ``i`` sits at absolute position
``q_start[b] + i`` and causally attends every key at
``t <= q_start[b] + i``, gathered through the row's page table.  With
``q_len = 1`` this computes exactly the paged flash-decode step
(``q_start`` is the row's decode position); with ``q_len = C`` it is the
chunked paged prefill step — the kernel *generalizes*
:mod:`repro.kernels.prefill_attention` and
:mod:`repro.kernels.paged_attention` into the one program the engine
launches per tick, instead of one of each.

Grid = (rows, kv_heads, pages), page sweep innermost: the online-softmax
accumulators (acc, m, l) live in VMEM scratch sized ``[C*G, ...]`` (chunk
queries × GQA group flattened into the flash row dim) and persist across
each (row, head)'s page sweep.  The page table and the per-row
``q_start``/``q_len`` scalars are scalar-prefetched
(:class:`pltpu.PrefetchScalarGridSpec`) so the KV block DMA of grid step
``(b, k, j)`` gathers through ``page_table[b, j]`` in the BlockSpec index
map.  Pages starting after the row's last live query
(``j*bs > q_start + q_len - 1``), pages wholly behind the sliding window
of the row's first query, and every page of a ``q_len == 0`` row are
``pl.when``-skipped (no FLOPs).  int8 KV dequantizes in-kernel: per-token
scales fold into the score matrix (k) and attention probs (v).

Queries at ``i >= q_len[b]`` (the padded tail of a final chunk, or the
``C-1`` padding slots of a decode row in a mixed-width batch) produce
**unspecified** output — every key is masked, the softmax denominator
clamps; callers read only position ``q_len - 1`` (the engine's
next-token logits).

``interpret=True`` runs the same body through the Pallas interpreter —
the off-TPU path used by this container and the tests; the jnp oracle is
:func:`repro.kernels.ref.mixed_attention_ref`.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _mixed_kernel(pt_ref, start_ref, qlen_ref, q_ref, k_ref, v_ref,
                  o_ref, acc_ref, m_ref, l_ref, *, ks_ref, vs_ref,
                  bs: int, C: int, G: int, scale: float, window,
                  np_: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = start_ref[b]
    qlen = qlen_ref[b]
    last = start + qlen - 1                # abs position of last live query
    live = (qlen > 0) & (j * bs <= last)
    if window is not None:
        # first query's window lower bound; later queries see more
        live &= j * bs + bs - 1 > start - window

    @pl.when(live)
    def _accumulate():
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(C * G, -1)
        k = k_ref[0, :, 0].astype(jnp.float32)         # [bs, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)         # [bs, hd]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if ks_ref is not None:
            s = s * ks_ref[0, :, 0][None, :]           # fused k dequant
        ci = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        pq = start + ci                                # abs query positions
        t = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (t <= pq) & (ci < qlen)
        if window is not None:
            mask &= t > pq - window
        s = jnp.where(mask, s, _NEG)

        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        corr = jnp.exp(m_old - m_new)
        e = jnp.exp(s - m_new[:, None])
        e = jnp.where(mask, e, 0.0)        # fully-masked rows: e would be 1
        l_ref[...] = l_ref[...] * corr + jnp.sum(e, axis=1)
        if vs_ref is not None:
            e = e * vs_ref[0, :, 0][None, :]           # fused v dequant
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            e, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == np_ - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0] = (acc_ref[...] / denom).reshape(
            C, G, o_ref.shape[-1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def mixed_attention(q, k_pages, v_pages, page_table, q_start, q_len,
                    *, k_scale=None, v_scale=None, window=None,
                    interpret: bool = False):
    """One unified mixed prefill+decode step over a block-paged KV pool.

    q           [B, C, KV, G, hd] token-batch queries (C slots per row)
    k_pages     [N, bs, KV, hd]   shared KV block pool (f32/bf16 or int8)
    v_pages     [N, bs, KV, hd]
    page_table  [B, P] int32      block id of page j of row b (0 = null)
    q_start     [B]    int32      absolute position of slot 0's query
                                  (prefill: chunk start; decode: position)
    q_len       [B]    int32      live queries this tick — C/tail for a
                                  prefill chunk, 1 for a decode token,
                                  0 for a stalled or idle row (skipped)
    k_scale     [N, bs, KV] f32   per-token dequant scales (int8 pool)
    v_scale     [N, bs, KV] f32
    window      sliding-window size (None = full causal)

    Every live query's own key must be scattered into the pool before
    the call (query i attends keys up to and including ``q_start + i``).
    Output positions ``i >= q_len[b]`` are unspecified; ``q_len == 0``
    rows output zeros.  Returns [B, C, KV, G, hd] in q's dtype.
    """
    B, C, KV, G, hd = q.shape
    bs = k_pages.shape[1]
    P = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    def idx_q(b, k, j, pt, st, ql):
        return (b, 0, k, 0, 0)

    def idx_kv(b, k, j, pt, st, ql):
        return (pt[b, j], 0, k, 0)

    def idx_sc(b, k, j, pt, st, ql):
        return (pt[b, j], 0, k)

    in_specs = [
        pl.BlockSpec((1, C, 1, G, hd), idx_q),
        pl.BlockSpec((1, bs, 1, hd), idx_kv),
        pl.BlockSpec((1, bs, 1, hd), idx_kv),
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), idx_sc),
                     pl.BlockSpec((1, bs, 1), idx_sc)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _mixed_kernel, bs=bs, C=C, G=G, scale=scale, window=window, np_=P)

    def body(pt_ref, start_ref, qlen_ref, *rest):
        if quant:
            (q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
             acc_ref, m_ref, l_ref) = rest
        else:
            q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = rest
            ks_ref = vs_ref = None
        kernel(pt_ref, start_ref, qlen_ref, q_ref, k_ref, v_ref,
               o_ref, acc_ref, m_ref, l_ref, ks_ref=ks_ref, vs_ref=vs_ref)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KV, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, C, 1, G, hd), idx_q),
        scratch_shapes=[
            pltpu.VMEM((C * G, hd), jnp.float32),   # acc
            pltpu.VMEM((C * G,), jnp.float32),      # running max m
            pltpu.VMEM((C * G,), jnp.float32),      # running Σexp l
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, KV, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, q_start, q_len, *operands)
