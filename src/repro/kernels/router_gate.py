"""MoE router gate (Pallas TPU kernel): fused softmax + top-k + renorm.

Every token of every MoE layer runs this (kimi-k2: 60 layers x 1M tokens
per train step).  The fused kernel does one VMEM pass over the expert
logits per row tile: softmax statistics, K iterative argmax extractions
(K is small and static — unrolled), and gate renormalization, without
materializing the full softmax in HBM.

Grid: (row_tiles,); the expert dim lives in one block (E <= 1024 covers
every assigned config; padded to the lane multiple with -inf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8
_NEG = -1e30


def _router_kernel(x_ref, gates_ref, idx_ref, *, k: int, e: int):
    x = x_ref[...].astype(jnp.float32)                     # [R, Ep]
    m = jnp.max(x, axis=1, keepdims=True)
    p = jnp.exp(x - m)
    s = jnp.sum(p, axis=1, keepdims=True)

    cur = x
    cols = jax.lax.broadcasted_iota(jnp.int32, cur.shape, 1)
    total = jnp.zeros((x.shape[0],), jnp.float32)
    gates = []
    idxs = []
    for j in range(k):                                      # static unroll
        best = jnp.max(cur, axis=1)
        arg = jnp.argmax(cur, axis=1).astype(jnp.int32)
        gate = jnp.exp(best - m[:, 0]) / s[:, 0]
        gates.append(gate)
        idxs.append(arg)
        total = total + gate
        cur = jnp.where(cols == arg[:, None], _NEG, cur)

    denom = jnp.maximum(total, 1e-9)
    for j in range(k):
        gates_ref[:, j] = gates[j] / denom
        idx_ref[:, j] = idxs[j]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def router_gate(logits, k: int, *, interpret: bool = False):
    """logits [..., E] -> (gates [..., k] renormalized, idx [..., k])."""
    orig = logits.shape[:-1]
    E = logits.shape[-1]
    x = logits.reshape(-1, E)
    R = x.shape[0]
    rpad = (-R) % ROW_TILE
    epad = (-E) % 128
    if rpad or epad:
        x = jnp.pad(x, ((0, rpad), (0, epad)), constant_values=_NEG)
    Rp = R + rpad

    gates, idx = pl.pallas_call(
        functools.partial(_router_kernel, k=k, e=E),
        grid=(Rp // ROW_TILE,),
        in_specs=[pl.BlockSpec((ROW_TILE, E + epad), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((ROW_TILE, k), lambda i: (i, 0)),
                   pl.BlockSpec((ROW_TILE, k), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((Rp, k), jnp.float32),
                   jax.ShapeDtypeStruct((Rp, k), jnp.int32)),
        interpret=interpret,
    )(x)
    return (gates[:R].reshape(*orig, k), idx[:R].reshape(*orig, k))
