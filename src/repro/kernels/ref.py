"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
the per-kernel tests assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def confidence_gate_ref(logits):
    x = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(x, axis=-1)
    p = jax.nn.softmax(x, axis=-1)
    return {
        "conf": jnp.max(p, axis=-1),
        "entropy": -jnp.sum(p * jax.nn.log_softmax(x, -1), axis=-1),
        "argmax": jnp.argmax(x, axis=-1).astype(jnp.int32),
        "logz": logz,
    }


def router_gate_ref(logits, k: int):
    """softmax -> top-k -> renormalize (the jnp path in models.blocks)."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(p, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, idx.astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        scale=None):
    """q [B,H,S,d]; k,v [B,KV,T,d] (GQA: H % KV == 0)."""
    B, H, S, d = q.shape
    KV = k.shape[1]
    G = H // KV
    if scale is None:
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qg = q.reshape(B, KV, G, S, d).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32)) * scale
    T = k.shape[2]
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(B, H, S, d).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, page_table, pos, *,
                        k_scale=None, v_scale=None, window=None):
    """Gather-then-attend oracle for the paged decode kernel.

    q [B,KV,G,hd]; k_pages/v_pages [N,bs,KV,hd] (int8 with scales or
    float); page_table [B,P] int32; pos [B] int32.  Returns [B,KV,G,hd].
    """
    B, KV, G, hd = q.shape
    bs = k_pages.shape[1]
    P = page_table.shape[1]
    k = k_pages[page_table].astype(jnp.float32)       # [B,P,bs,KV,hd]
    v = v_pages[page_table].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[page_table].astype(jnp.float32)[..., None]
        v = v * v_scale[page_table].astype(jnp.float32)[..., None]
    T = P * bs
    k = k.reshape(B, T, KV, hd)
    v = v.reshape(B, T, KV, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32), k) * scale
    t_idx = jnp.arange(T)[None, None, None, :]
    mask = t_idx <= pos[:, None, None, None]
    if window is not None:
        mask &= t_idx > pos[:, None, None, None] - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v)
    return out.astype(q.dtype)


def paged_prefill_attention_ref(q, k_pages, v_pages, page_table, q_start,
                                q_len, *, k_scale=None, v_scale=None,
                                window=None):
    """Gather-then-attend oracle for the chunked paged *prefill* kernel.

    q [B,C,KV,G,hd] — a chunk of C query tokens per row; row b's query i
    sits at absolute position ``q_start[b] + i`` and attends keys at
    ``t <= q_start[b] + i`` gathered through ``page_table`` [B,P].
    Queries at ``i >= q_len[b]`` are padding: their output is zeroed here
    (the kernel leaves them unspecified — compare valid queries only).
    Returns [B,C,KV,G,hd].
    """
    B, C, KV, G, hd = q.shape
    bs = k_pages.shape[1]
    P = page_table.shape[1]
    k = k_pages[page_table].astype(jnp.float32)       # [B,P,bs,KV,hd]
    v = v_pages[page_table].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[page_table].astype(jnp.float32)[..., None]
        v = v * v_scale[page_table].astype(jnp.float32)[..., None]
    T = P * bs
    k = k.reshape(B, T, KV, hd)
    v = v.reshape(B, T, KV, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bckgd,btkd->bkgct", q.astype(jnp.float32), k) * scale
    pos_q = q_start[:, None] + jnp.arange(C)[None, :]     # [B,C]
    t_idx = jnp.arange(T)[None, None, None, None, :]
    pq = pos_q[:, None, None, :, None]
    mask = t_idx <= pq
    if window is not None:
        mask &= t_idx > pq - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgct,btkd->bckgd", p, v)
    valid = (jnp.arange(C)[None, :] < q_len[:, None])[:, :, None, None, None]
    return jnp.where(valid, out, 0.0).astype(q.dtype)


def mixed_attention_ref(q, k_pages, v_pages, page_table, q_start, q_len, *,
                        k_scale=None, v_scale=None, window=None):
    """Gather-then-attend oracle for the unified mixed prefill+decode
    kernel (``kernels/mixed_attention.py``).

    One token batch serves every live row: row b's ``q_len[b]`` live
    queries start at absolute position ``q_start[b]`` — a prefill chunk
    (``q_len = C`` or the final-chunk tail), a single decode token
    (``q_len = 1`` at the row's decode position), or nothing at all
    (``q_len = 0``, output zeroed).  The causal-over-pages math is the
    chunked-prefill contract with decode as its width-1 special case, so
    the oracle delegates to :func:`paged_prefill_attention_ref` (a
    ``q_len = 1`` row there *is* a paged decode step — pinned against
    :func:`paged_attention_ref` in the tests).
    """
    return paged_prefill_attention_ref(
        q, k_pages, v_pages, page_table, q_start, q_len,
        k_scale=k_scale, v_scale=v_scale, window=window)


def ragged_attention_ref(q, k_pages, v_pages, page_table, q_start, q_len,
                         *, k_scale=None, v_scale=None, window=None):
    """Gather-then-attend oracle for the ragged flat token-batch kernel
    (``kernels/ragged_attention.py``).

    q is ``[W, KV, G, hd]`` — the tick's tokens packed contiguously:
    row b owns flat slots ``[row_start[b], row_start[b] + q_len[b])``
    where ``row_start`` is the exclusive prefix sum of ``q_len``.  Flat
    slot ``t`` of row b sits at absolute position
    ``q_start[b] + t - row_start[b]`` and attends keys gathered through
    that row's page table, exactly as in
    :func:`paged_prefill_attention_ref`.  Padding slots past
    ``sum(q_len)`` output zeros.  Returns ``[W, KV, G, hd]``.
    """
    W, KV, G, hd = q.shape
    B, P = page_table.shape
    bs = k_pages.shape[1]
    k = k_pages[page_table].astype(jnp.float32)       # [B,P,bs,KV,hd]
    v = v_pages[page_table].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[page_table].astype(jnp.float32)[..., None]
        v = v * v_scale[page_table].astype(jnp.float32)[..., None]
    T = P * bs
    k = k.reshape(B, T, KV, hd)
    v = v.reshape(B, T, KV, hd)
    csum = jnp.cumsum(q_len)
    tok = jnp.arange(W)
    row = jnp.minimum(jnp.searchsorted(csum, tok, side="right"), B - 1)
    valid = tok < csum[-1]
    row_start = csum - q_len
    pos_q = q_start[row] + (tok - row_start[row])     # [W] abs positions
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("wkgd,wtkd->wkgt", q.astype(jnp.float32), k[row]) * scale
    t_idx = jnp.arange(T)[None, None, None, :]
    pq = pos_q[:, None, None, None]
    mask = t_idx <= pq
    if window is not None:
        mask &= t_idx > pq - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("wkgt,wtkd->wkgd", p, v[row])
    return jnp.where(valid[:, None, None, None], out, 0.0).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u):
    """All inputs [B,H,T,hd] except u [H,hd].  Returns y [B,H,T,hd].

        y_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    """
    B, H, T, hd = r.shape
    r32, k32, v32, w32 = (a.astype(jnp.float32) for a in (r, k, v, w))
    u32 = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u32[..., None] * kv)
        return w_t[..., None] * S + kv, y

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(a.transpose(2, 0, 1, 3) for a in (r32, k32, v32, w32))
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype)


def mamba_scan_ref(x, dt, B_t, C_t, A):
    """Selective scan.  x,dt [B,T,d]; B_t,C_t [B,T,n]; A [d,n].  y [B,T,d].

        h_t = exp(dt_t A) ⊙ h_{t-1} + (dt_t x_t) B_tᵀ;  y_t = h_t · C_t
    """
    x32, dt32, Bt, Ct = (a.astype(jnp.float32) for a in (x, dt, B_t, C_t))
    A32 = A.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * A32)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    Bsz, T, d = x.shape
    n = A.shape[1]
    h0 = jnp.zeros((Bsz, d, n), jnp.float32)
    xs = (x32.transpose(1, 0, 2), dt32.transpose(1, 0, 2),
          Bt.transpose(1, 0, 2), Ct.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype)
