"""Chunked paged prefill attention (Pallas TPU kernel).

The serving engine's mixed-length prefill path: each batch row processes a
fixed-size **chunk** of ``C`` prompt tokens whose keys/values were just
scattered into the shared block-paged KV pool (the same
``[num_blocks, block_size, KV, hd]`` layout :mod:`paged_attention`
decodes from).  Row ``b``'s query ``i`` sits at absolute position
``q_start[b] + i`` and attends — causally — every key at
``t <= q_start[b] + i``, gathered through the row's page table.  Rows in
the same chunk batch may be at completely different depths (one row on
prompt tokens 256..383 while another prefills tokens 0..6), which is what
lets the engine batch a 7-token prompt next to a 900-token one with no
cross-row padding beyond the last chunk.

Grid = (rows, kv_heads, pages) with the page sweep innermost, exactly as
in the paged decode kernel: the online-softmax accumulators (acc, m, l)
live in VMEM scratch, now sized ``[C*G, ...]`` — the chunk's queries and
GQA group heads flattened into one flash row dim.  The page table and the
per-row ``q_start``/``q_len`` scalars are scalar-prefetched
(:class:`pltpu.PrefetchScalarGridSpec`) so the KV block DMA of step
``(b, k, j)`` gathers through ``page_table[b, j]`` in the BlockSpec index
map.  Pages that start after the row's last valid query
(``j*bs > q_start + q_len - 1``) are ``pl.when``-skipped, as are pages
wholly behind the sliding window of the row's *first* query; rows with
``q_len == 0`` (not prefilling this tick, or stalled on block
exhaustion) skip every page and output zeros.

Queries at ``i >= q_len[b]`` (the padded tail of a row's final chunk)
produce **unspecified** output — every key is masked, so the softmax
denominator clamps; callers discard those positions (the engine reads
logits only at ``q_len - 1``).  int8 KV dequantizes in-kernel: per-token
scales fold into the score matrix (k) and attention probs (v).

``interpret=True`` runs the same body through the Pallas interpreter —
the off-TPU path used by this container and the tests; the jnp oracle is
:func:`repro.kernels.ref.paged_prefill_attention_ref`.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _prefill_kernel(pt_ref, start_ref, qlen_ref, q_ref, k_ref, v_ref,
                    o_ref, acc_ref, m_ref, l_ref, *, ks_ref, vs_ref,
                    bs: int, C: int, G: int, scale: float, window,
                    np_: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = start_ref[b]
    qlen = qlen_ref[b]
    last = start + qlen - 1                # abs position of last live query
    live = (qlen > 0) & (j * bs <= last)
    if window is not None:
        # first query's window lower bound; later queries see more
        live &= j * bs + bs - 1 > start - window

    @pl.when(live)
    def _accumulate():
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(C * G, -1)
        k = k_ref[0, :, 0].astype(jnp.float32)         # [bs, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)         # [bs, hd]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if ks_ref is not None:
            s = s * ks_ref[0, :, 0][None, :]           # fused k dequant
        ci = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        pq = start + ci                                # abs query positions
        t = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (t <= pq) & (ci < qlen)
        if window is not None:
            mask &= t > pq - window
        s = jnp.where(mask, s, _NEG)

        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        corr = jnp.exp(m_old - m_new)
        e = jnp.exp(s - m_new[:, None])
        e = jnp.where(mask, e, 0.0)        # fully-masked rows: e would be 1
        l_ref[...] = l_ref[...] * corr + jnp.sum(e, axis=1)
        if vs_ref is not None:
            e = e * vs_ref[0, :, 0][None, :]           # fused v dequant
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            e, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == np_ - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0] = (acc_ref[...] / denom).reshape(
            C, G, o_ref.shape[-1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_prefill_attention(q, k_pages, v_pages, page_table, q_start, q_len,
                            *, k_scale=None, v_scale=None, window=None,
                            interpret: bool = False):
    """One chunked-prefill attention step over a block-paged KV pool.

    q           [B, C, KV, G, hd] chunk queries (C tokens per row)
    k_pages     [N, bs, KV, hd]   shared KV block pool (f32/bf16 or int8)
    v_pages     [N, bs, KV, hd]
    page_table  [B, P] int32      block id of page j of row b (0 = null)
    q_start     [B]    int32      absolute position of chunk token 0
    q_len       [B]    int32      live tokens this chunk (0 = skip row)
    k_scale     [N, bs, KV] f32   per-token dequant scales (int8 pool)
    v_scale     [N, bs, KV] f32
    window      sliding-window size (None = full causal)

    The chunk's own keys must be scattered into the pool before the call
    (query i attends keys up to and including its own position).  Output
    positions ``i >= q_len[b]`` are unspecified.  Returns
    [B, C, KV, G, hd] in q's dtype.
    """
    B, C, KV, G, hd = q.shape
    bs = k_pages.shape[1]
    P = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    def idx_q(b, k, j, pt, st, ql):
        return (b, 0, k, 0, 0)

    def idx_kv(b, k, j, pt, st, ql):
        return (pt[b, j], 0, k, 0)

    def idx_sc(b, k, j, pt, st, ql):
        return (pt[b, j], 0, k)

    in_specs = [
        pl.BlockSpec((1, C, 1, G, hd), idx_q),
        pl.BlockSpec((1, bs, 1, hd), idx_kv),
        pl.BlockSpec((1, bs, 1, hd), idx_kv),
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), idx_sc),
                     pl.BlockSpec((1, bs, 1), idx_sc)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _prefill_kernel, bs=bs, C=C, G=G, scale=scale, window=window, np_=P)

    def body(pt_ref, start_ref, qlen_ref, *rest):
        if quant:
            (q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
             acc_ref, m_ref, l_ref) = rest
        else:
            q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = rest
            ks_ref = vs_ref = None
        kernel(pt_ref, start_ref, qlen_ref, q_ref, k_ref, v_ref,
               o_ref, acc_ref, m_ref, l_ref, ks_ref=ks_ref, vs_ref=vs_ref)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KV, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, C, 1, G, hd), idx_q),
        scratch_shapes=[
            pltpu.VMEM((C * G, hd), jnp.float32),   # acc
            pltpu.VMEM((C * G,), jnp.float32),      # running max m
            pltpu.VMEM((C * G,), jnp.float32),      # running Σexp l
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, KV, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, q_start, q_len, *operands)
