"""Chunked paged prefill attention (Pallas TPU kernel).

The serving engine's mixed-length prefill path: each batch row processes a
fixed-size **chunk** of ``C`` prompt tokens whose keys/values were just
scattered into the shared block-paged KV pool (the same
``[num_blocks, block_size, KV, hd]`` layout :mod:`paged_attention`
decodes from).  Row ``b``'s query ``i`` sits at absolute position
``q_start[b] + i`` and attends — causally — every key at
``t <= q_start[b] + i``, gathered through the row's page table.  Rows in
the same chunk batch may be at completely different depths (one row on
prompt tokens 256..383 while another prefills tokens 0..6), which is what
lets the engine batch a 7-token prompt next to a 900-token one with no
cross-row padding beyond the last chunk.

The chunked-prefill contract is the prefill-only restriction of the
**unified mixed prefill+decode** contract, so the single kernel body
lives in :mod:`repro.kernels.mixed_attention` (grid
``(rows, kv_heads, pages)``, scalar-prefetched page table +
``q_start``/``q_len``, online-softmax accumulators ``[C*G, hd]`` in VMEM
scratch, in-kernel int8 dequant and sliding windows — see that module
and ``docs/kernels.md`` for the layout) and this wrapper delegates to
it: a prefill chunk is just a row with ``q_len`` up to ``C``, exactly as
a decode row is one with ``q_len = 1``.  Keeping the public name lets
the engine's split path (``use_unified_step=False``) and the unified
path share one compiled body — bit-identical by construction, never by
maintenance.

Queries at ``i >= q_len[b]`` (the padded tail of a row's final chunk)
produce **unspecified** output; rows with ``q_len == 0`` (not prefilling
this tick, or stalled on block exhaustion) skip every page and output
zeros.  ``interpret=True`` runs the kernel body through the Pallas
interpreter — the off-TPU path used by this container and the tests; the
jnp oracle is :func:`repro.kernels.ref.paged_prefill_attention_ref`.
"""
from __future__ import annotations

from repro.kernels.mixed_attention import mixed_attention


def paged_prefill_attention(q, k_pages, v_pages, page_table, q_start, q_len,
                            *, k_scale=None, v_scale=None, window=None,
                            interpret: bool = False):
    """One chunked-prefill attention step over a block-paged KV pool.

    q           [B, C, KV, G, hd] chunk queries (C tokens per row)
    k_pages     [N, bs, KV, hd]   shared KV block pool (f32/bf16 or int8)
    v_pages     [N, bs, KV, hd]
    page_table  [B, P] int32      block id of page j of row b (0 = null)
    q_start     [B]    int32      absolute position of chunk token 0
    q_len       [B]    int32      live tokens this chunk (0 = skip row)
    k_scale     [N, bs, KV] f32   per-token dequant scales (int8 pool)
    v_scale     [N, bs, KV] f32
    window      sliding-window size (None = full causal)

    The chunk's own keys must be scattered into the pool before the call
    (query i attends keys up to and including its own position).  Output
    positions ``i >= q_len[b]`` are unspecified.  Returns
    [B, C, KV, G, hd] in q's dtype.  Delegates to
    :func:`repro.kernels.mixed_attention.mixed_attention` (the
    generalized kernel this contract restricts).
    """
    return mixed_attention(q, k_pages, v_pages, page_table, q_start, q_len,
                           k_scale=k_scale, v_scale=v_scale, window=window,
                           interpret=interpret)
