"""Mamba-1 selective scan (Pallas TPU kernel).

Recurrence (diagonal A, per-channel dt, shared B_t/C_t across channels):

    h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + (dt_t ⊙ x_t) B_tᵀ      h ∈ R^{d x n}
    y_t = h_t · C_t

Grid = (batch, d_inner_tiles, time_chunks), time innermost; the state tile
h [d_tile, n] persists in VMEM scratch across chunks.  Channels are
independent, so d_inner is tiled freely; n (= d_state, 16) rides in the
lane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

T_CHUNK = 128
D_TILE = 512


def _mamba_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, h_ref, *,
                  ct: int):
    t0 = pl.program_id(2)

    @pl.when(t0 == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)           # [ct, dt]
    dt = dt_ref[0].astype(jnp.float32)         # [ct, dt]
    bt = b_ref[0].astype(jnp.float32)          # [ct, n]
    c = c_ref[0].astype(jnp.float32)           # [ct, n]
    A = a_ref[...].astype(jnp.float32)         # [dt, n]

    def step(t, h):
        da = jnp.exp(dt[t][:, None] * A)                    # [dt, n]
        h = da * h + (dt[t] * x[t])[:, None] * bt[t][None, :]
        y = jnp.sum(h * c[t][None, :], axis=1)              # [dt]
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, ct, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def mamba_scan(x, dt, B_t, C_t, A, *, interpret: bool = False):
    """x,dt [B,T,d]; B_t,C_t [B,T,n]; A [d,n].  Returns y [B,T,d] (f32)."""
    Bsz, T, d = x.shape
    n = A.shape[1]
    tpad = (-T) % T_CHUNK
    if tpad:
        pad3 = lambda a: jnp.pad(a, ((0, 0), (0, tpad), (0, 0)))  # noqa: E731
        x, dt, B_t, C_t = pad3(x), pad3(dt), pad3(B_t), pad3(C_t)
    dpad = (-d) % D_TILE if d > D_TILE else 0
    dtile = min(d, D_TILE)
    if dpad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, dpad)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, dpad)))
        A = jnp.pad(A, ((0, dpad), (0, 0)))
    Tp, dp = T + tpad, d + dpad
    nt, nd = Tp // T_CHUNK, dp // dtile

    chan_spec = pl.BlockSpec((1, T_CHUNK, dtile), lambda b, i, t: (b, t, i))
    state_spec = pl.BlockSpec((1, T_CHUNK, n), lambda b, i, t: (b, t, 0))
    out = pl.pallas_call(
        functools.partial(_mamba_kernel, ct=T_CHUNK),
        grid=(Bsz, nd, nt),
        in_specs=[chan_spec, chan_spec, state_spec, state_spec,
                  pl.BlockSpec((dtile, n), lambda b, i, t: (i, 0))],
        out_specs=chan_spec,
        out_shape=jax.ShapeDtypeStruct((Bsz, Tp, dp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dtile, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, B_t, C_t, A)
    return out[:, :T, :d]
