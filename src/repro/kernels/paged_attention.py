"""Paged flash-decode attention (Pallas TPU kernel).

GQA decode over a block-paged KV cache: keys/values live in a shared pool
of fixed-size blocks ``[num_blocks, block_size, KV, hd]`` and each query
row owns a page table ``[max_pages]`` of block ids covering its sequence.
One new token per row attends to its own pages only — decode attention
work is O(Σ per-row live tokens) instead of O(rows · max_seq), and arena
memory is decoupled from ``prompt_len + gen_len``.

Grid = (rows, kv_heads, pages) with the page sweep innermost: the online
softmax accumulators (acc, m, l — the streaming pattern from
``confidence_gate.py``) live in VMEM scratch and persist across the page
sweep of each (row, head).  The page table and per-row positions are
scalar-prefetched (:class:`pltpu.PrefetchScalarGridSpec`) so the KV block
DMA of step ``(b, k, j)`` is gathered through ``page_table[b, j]`` in the
BlockSpec index map — the kernel never sees a dense ``[rows, max_seq]``
arena.

Pages past a row's depth are skipped with ``pl.when`` (no FLOPs); their
table entries point at block 0 (the reserved null block) so the gather
stays in-bounds and the pipeline re-fetches a block it already holds.
Sliding windows additionally skip pages that fall entirely behind the
window.  int8 KV is dequantized in-kernel: per-token scales fold into the
score matrix (k) and attention probs (v), so the pool is read at
1 byte/element.

``interpret=True`` runs the same kernel body through the Pallas
interpreter — the path used off-TPU (this container) and by the tests.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _paged_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref,
                  o_ref, acc_ref, m_ref, l_ref, *, ks_ref, vs_ref,
                  bs: int, scale: float, window, np_: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    p = pos_ref[b]
    live = j * bs <= p                     # page starts at or before pos
    if window is not None:
        live &= j * bs + bs - 1 > p - window   # page not wholly behind it

    @pl.when(live)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32)            # [G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)         # [bs, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)         # [bs, hd]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if ks_ref is not None:
            s = s * ks_ref[0, :, 0][None, :]           # fused k dequant
        t = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = t <= p
        if window is not None:
            mask &= t > p - window
        s = jnp.where(mask, s, _NEG)

        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        corr = jnp.exp(m_old - m_new)
        e = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + jnp.sum(e, axis=1)
        if vs_ref is not None:
            e = e * vs_ref[0, :, 0][None, :]           # fused v dequant
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            e, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == np_ - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret"))
def paged_attention(q, k_pages, v_pages, page_table, pos, *,
                    k_scale=None, v_scale=None, window=None,
                    interpret: bool = False):
    """One decode step over a block-paged KV pool.

    q           [B, KV, G, hd]   this step's queries (rows at any depth)
    k_pages     [N, bs, KV, hd]  shared KV block pool (f32/bf16 or int8)
    v_pages     [N, bs, KV, hd]
    page_table  [B, P] int32     block id of page j of row b (0 = null)
    pos         [B]    int32     per-row decode position; keys at t <= pos
                                 are attended (the key at ``pos`` must be
                                 written before the call)
    k_scale     [N, bs, KV] f32  per-token dequant scales (int8 pool only)
    v_scale     [N, bs, KV] f32
    window      sliding-window size (None = full causal)

    Returns [B, KV, G, hd] in q's dtype.
    """
    B, KV, G, hd = q.shape
    N, bs = k_pages.shape[0], k_pages.shape[1]
    P = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    def idx_q(b, k, j, pt, pp):
        return (b, k, 0, 0)

    def idx_kv(b, k, j, pt, pp):
        return (pt[b, j], 0, k, 0)

    def idx_sc(b, k, j, pt, pp):
        return (pt[b, j], 0, k)

    in_specs = [
        pl.BlockSpec((1, 1, G, hd), idx_q),
        pl.BlockSpec((1, bs, 1, hd), idx_kv),
        pl.BlockSpec((1, bs, 1, hd), idx_kv),
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), idx_sc),
                     pl.BlockSpec((1, bs, 1), idx_sc)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _paged_kernel, bs=bs, scale=scale, window=window, np_=P)

    def body(pt_ref, pos_ref, *rest):
        if quant:
            (q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
             acc_ref, m_ref, l_ref) = rest
        else:
            q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = rest
            ks_ref = vs_ref = None
        kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref,
               o_ref, acc_ref, m_ref, l_ref, ks_ref=ks_ref, vs_ref=vs_ref)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd), idx_q),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),   # acc
            pltpu.VMEM((G,), jnp.float32),      # running max m
            pltpu.VMEM((G,), jnp.float32),      # running Σexp l
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, pos, *operands)
