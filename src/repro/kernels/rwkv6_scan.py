"""RWKV-6 WKV scan (Pallas TPU kernel).

Recurrence per head (state S in R^{hd x hd}, key-major):

    y_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Grid = (B, H, time_chunks), time innermost: the state persists in VMEM
scratch across chunks (TPU grid iterations are sequential per core — the
idiomatic TPU replacement for a GPU selective-scan block).  Inside a chunk
the recurrence is stepped exactly (fori_loop of rank-1 VPU updates on the
VMEM-resident state): numerically identical to the reference, no
log-space chunk algebra needed.

hd = 64 for rwkv6-3b: the state tile is 64x64 f32 = 16 KiB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

T_CHUNK = 128


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, ct: int):
    t0 = pl.program_id(2)

    @pl.when(t0 == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)        # [ct, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # [hd]

    def step(t, carry):
        S = carry                               # [hd, hd]
        kv = k[t][:, None] * v[t][None, :]
        y = jnp.sum(r[t][:, None] * (S + u[:, None] * kv), axis=0)
        o_ref[0, 0, t, :] = y.astype(o_ref.dtype)
        return w[t][:, None] * S + kv

    S = jax.lax.fori_loop(0, ct, step, s_ref[...])
    s_ref[...] = S


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_scan(r, k, v, w, u, *, interpret: bool = False):
    """r,k,v,w [B,H,T,hd]; u [H,hd].  Returns y [B,H,T,hd] (f32)."""
    B, H, T, hd = r.shape
    pad = (-T) % T_CHUNK
    if pad:
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))  # noqa: E731
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad), (0, 0)), constant_values=1.0)
    Tp = T + pad
    nt = Tp // T_CHUNK

    seq_spec = pl.BlockSpec((1, 1, T_CHUNK, hd), lambda b, h, t: (b, h, t, 0))
    out = pl.pallas_call(
        functools.partial(_wkv_kernel, ct=T_CHUNK),
        grid=(B, H, nt),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, hd), lambda b, h, t: (h, 0))],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Tp, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out[:, :, :T]
