"""Flash attention (Pallas TPU kernel): causal / sliding-window / GQA.

Online-softmax blocked attention.  Grid = (batch, q_heads, q_tiles,
kv_tiles) with the KV sweep innermost: the accumulator (o, m, l) lives in
VMEM scratch and persists across the kv tiles of one q tile (TPU grids run
sequentially per core).  GQA is handled in the kv BlockSpec index map
(query head h reads kv head h // group).

Tiles default to (128, 128): MXU-aligned on both matmul dims.  head_dim is
loaded whole (<= 256 for every assigned arch).  On real TPU a fully-masked
kv tile would be skipped via grid pruning; interpret-mode validation
computes it masked (correctness identical, noted for the roofline).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_TILE = 128
KV_TILE = 128
_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window, nk: int,
                  q_len: int, kv_len: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # [qt, d]
    k = k_ref[0, 0].astype(jnp.float32)                      # [kt, d]
    v = v_ref[0, 0].astype(jnp.float32)                      # [kt, d]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = iq * q_ref.shape[2] + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 0)
    kpos = jk * k_ref.shape[2] + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    mask = (qpos < q_len) & (kpos < kv_len)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    interpret: bool = False):
    """q [B,H,S,d]; k,v [B,KV,T,d] (H % KV == 0).  Returns [B,H,S,d]."""
    B, H, S, d = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(d)

    qpad = (-S) % Q_TILE
    kpad = (-T) % KV_TILE
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0))) if qpad else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0))) if kpad else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0))) if kpad else v
    Sp, Tp = S + qpad, T + kpad
    nq, nk = Sp // Q_TILE, Tp // KV_TILE

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, nk=nk,
        q_len=S, kv_len=T)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, Q_TILE, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, KV_TILE, d),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, KV_TILE, d),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q_TILE, d),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Q_TILE, d), jnp.float32),
            pltpu.VMEM((Q_TILE,), jnp.float32),
            pltpu.VMEM((Q_TILE,), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :S]
