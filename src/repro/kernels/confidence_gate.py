"""Fused cascade confidence gate (Pallas TPU kernel).

The paper's gate is `conf = max softmax(logits)` compared against δ.  At
LLM vocab sizes (up to 262k here) a naive implementation materializes the
full softmax: three HBM passes over the logits.  This kernel computes, in
ONE streaming pass over vocab tiles held in VMEM:

    * conf     = max softmax probability        (the paper's score)
    * entropy  = H(p)                           (alternative score)
    * argmax   = top-1 token id
    * logz     = logsumexp (for downstream temperature re-scaling)

using online-softmax accumulators (running max m, Σexp S, Σ(x-m)exp T):

    logZ = m + log S;  conf = exp(x_max - logZ);  H = logZ - (m + T/S)

Grid: (row_tiles, vocab_tiles), vocab innermost => the VMEM scratch
accumulators persist across the vocab sweep of each row tile (TPU grids
execute sequentially per core).  Tiles are (8, 1024): 8 sublanes x 8*128
lanes, 32 KiB of VMEM per tile at f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROW_TILE = 8
VOCAB_TILE = 1024
_NEG = -1e30


def _gate_kernel(x_ref, conf_ref, ent_ref, arg_ref, logz_ref,
                 m_ref, s_ref, t_ref, amax_ref, aidx_ref, *, nv: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.zeros_like(t_ref)
        amax_ref[...] = jnp.full_like(amax_ref, _NEG)
        aidx_ref[...] = jnp.zeros_like(aidx_ref)

    x = x_ref[...].astype(jnp.float32)                     # [R, VT]
    tile_max = jnp.max(x, axis=1)                          # [R]
    tile_arg = jnp.argmax(x, axis=1).astype(jnp.int32) + j * x.shape[1]

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, tile_max)
    corr = jnp.exp(m_old - m_new)                          # rescale factor
    e = jnp.exp(x - m_new[:, None])
    s_old = s_ref[...]
    s_ref[...] = s_old * corr + jnp.sum(e, axis=1)
    # re-center the Σ(x-m)e accumulator onto the new max:
    #   Σ(x-m_new)e^{x-m_new} = corr·[T_old + (m_old-m_new)·S_old] + tile term
    t_ref[...] = corr * (t_ref[...] + (m_old - m_new) * s_old) \
        + jnp.sum((x - m_new[:, None]) * e, axis=1)
    m_ref[...] = m_new

    upd = tile_max > amax_ref[...]
    amax_ref[...] = jnp.where(upd, tile_max, amax_ref[...])
    aidx_ref[...] = jnp.where(upd, tile_arg, aidx_ref[...])

    @pl.when(j == nv - 1)
    def _finish():
        m = m_ref[...]
        s = s_ref[...]
        logz = m + jnp.log(s)
        conf_ref[...] = jnp.exp(amax_ref[...] - logz)
        ent_ref[...] = jnp.log(s) - t_ref[...] / s         # logZ - E[x-m]... see note
        arg_ref[...] = aidx_ref[...]
        logz_ref[...] = logz


# note: H = logZ - E[x] = (m + log S) - (m + T/S) = log S - T/S.


@functools.partial(jax.jit, static_argnames=("interpret",))
def confidence_gate(logits, *, interpret: bool = False):
    """logits [..., V] -> dict(conf, entropy, argmax, logz), each [...]."""
    orig_shape = logits.shape[:-1]
    V = logits.shape[-1]
    x = logits.reshape(-1, V)
    R = x.shape[0]

    rpad = (-R) % ROW_TILE
    vpad = (-V) % VOCAB_TILE
    if rpad or vpad:
        x = jnp.pad(x, ((0, rpad), (0, vpad)), constant_values=_NEG)
    Rp, Vp = x.shape
    nr, nv = Rp // ROW_TILE, Vp // VOCAB_TILE

    out_shapes = (
        jax.ShapeDtypeStruct((Rp,), jnp.float32),   # conf
        jax.ShapeDtypeStruct((Rp,), jnp.float32),   # entropy
        jax.ShapeDtypeStruct((Rp,), jnp.int32),     # argmax
        jax.ShapeDtypeStruct((Rp,), jnp.float32),   # logz
    )
    row_spec = pl.BlockSpec((ROW_TILE,), lambda i, j: (i,))
    conf, ent, arg, logz = pl.pallas_call(
        functools.partial(_gate_kernel, nv=nv),
        grid=(nr, nv),
        in_specs=[pl.BlockSpec((ROW_TILE, VOCAB_TILE), lambda i, j: (i, j))],
        out_specs=(row_spec, row_spec, row_spec, row_spec),
        out_shape=out_shapes,
        scratch_shapes=[
            # m, s, t, amax (f32) + aidx (i32), one slot per row in tile
            pltpu.VMEM((ROW_TILE,), jnp.float32),
            pltpu.VMEM((ROW_TILE,), jnp.float32),
            pltpu.VMEM((ROW_TILE,), jnp.float32),
            pltpu.VMEM((ROW_TILE,), jnp.float32),
            pltpu.VMEM((ROW_TILE,), jnp.int32),
        ],
        interpret=interpret,
    )(x)

    def cut(a):
        return a[:R].reshape(orig_shape)

    return {"conf": cut(conf), "entropy": cut(ent),
            "argmax": cut(arg), "logz": cut(logz)}
