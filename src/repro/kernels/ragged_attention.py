"""Ragged flat token-batch attention (Pallas TPU kernel).

The O(live tokens) form of :mod:`repro.kernels.mixed_attention`.  The
padded mixed kernel gives every row a width-``C`` query slice, so a
decode row (``q_len = 1``) still pays ``C×`` flash work; here the tick's
tokens pack **contiguously** into one flat ``[W]`` axis — row ``b`` owns
flat slots ``[row_start[b], row_start[b] + q_len[b])`` where
``row_start`` is the exclusive prefix sum of ``q_len`` and ``q_len[b]``
is *arbitrary* in ``[0, C]`` (not just ``{0, 1, chunk}``).  ``W`` is the
live-token total padded up to the engine's bucket width, so compute
scales with what is actually live, not ``rows × chunk``.

The grid sweeps flat token **tiles** of ``tile_q`` tokens instead of
rows.  A tile can span several rows (many decode rows pack into one
tile) and a row can span several tiles (a prefill chunk), so the wrapper
flattens the (tile, row) incidence into a **work list** — one grid step
per (tile, owning row, page) — sorted tile-major so each output tile is
resident for exactly one contiguous span of grid steps:

  grid = (work_items, pages),   work_items <= W/tile_q + B

All KV heads are handled inside one grid step (a static unrolled loop
with per-head accumulators) instead of a third grid dimension: the KV
block gather ``(1, bs, KV, hd)`` spans every head of the page, which
keeps the step count — the dominant cost both for TPU grid dispatch and
for the interpreter — at ``work_items × pages``.

``work_tile[w]``/``work_row[w]`` are scalar-prefetched
(:class:`pltpu.PrefetchScalarGridSpec`) together with the page table and
the per-row ``row_start``/``q_start``/``q_len`` scalars, so grid step
``(w, j)`` gathers KV block ``page_table[work_row[w], j]`` in the
BlockSpec index map.  The online-softmax accumulators (acc, m, l) live
in VMEM scratch sized ``[KV, tile_q*G, ...]`` and persist across a
tile's whole (row, page) span: ``work_first``/``work_last`` flags mark
the span's edges (init / normalize-and-write).  Tiles past the live
total get one padding work item (``work_row = -1``) so their output
still zero-fills.  Per step, the mask is the intersection of the tile's
flat slots with the owning row's range plus the causal/window test at
the row's absolute positions (``q_start[row] + slot - row_start[row]``).
Pages past the row's last in-tile query, pages wholly behind the
sliding window, and padding items are ``pl.when``-skipped (no FLOPs).
int8 KV dequantizes in-kernel exactly as in the mixed kernel.

``interpret=True`` runs the same body through the Pallas interpreter —
the off-TPU path used by this container and the tests; the jnp oracle
is :func:`repro.kernels.ref.ragged_attention_ref`.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def flat_work_layout(q_len, num_tiles: int, tile_q: int):
    """Flatten the (tile, row) incidence of a ragged batch (traced).

    Returns int32 arrays of length ``num_tiles + B``:
      work_tile   owning tile of each work item (tile-major sorted)
      work_row    owning row, or -1 for padding items
      work_first  1 on the first item of each tile (init accumulators)
      work_last   1 on the last item of each tile (normalize + write)
    plus ``row_start`` [B], the exclusive prefix sum of q_len (each
    row's first flat slot).

    Every tile gets at least one item: tiles past ``sum(q_len)`` receive
    a filler so their output block is still zero-written.  A row
    intersects a tile when its flat range overlaps the tile's slots; the
    total incidence count is at most ``num_tiles + B - 1``, so the fixed
    ``num_tiles + B`` work length never truncates.
    """
    i32 = jnp.int32
    q_len = q_len.astype(i32)
    B = q_len.shape[0]
    row_start = jnp.concatenate(
        [jnp.zeros((1,), i32), jnp.cumsum(q_len)])[:B]
    row_end = row_start + q_len
    tile_lo = (jnp.arange(num_tiles, dtype=i32) * tile_q)[:, None]
    inc = ((q_len[None, :] > 0)
           & (row_start[None, :] < tile_lo + tile_q)
           & (row_end[None, :] > tile_lo))                  # [nt, B]
    filler = jnp.sum(inc, axis=1, keepdims=True) == 0       # empty tiles
    mask = jnp.concatenate([inc, filler], axis=1).reshape(-1)
    flat = jnp.arange(num_tiles * (B + 1), dtype=i32)
    # real items keep their tile-major key; non-items sort after them
    order = jnp.argsort(jnp.where(mask, flat, flat + flat.shape[0]))
    sel = order[:num_tiles + B]
    real = jnp.take(mask, sel)
    tile_of = (sel // (B + 1)).astype(i32)
    col = (sel % (B + 1)).astype(i32)
    # padding items tail the last tile (row -1: skipped, never first)
    work_tile = jnp.where(real, tile_of, num_tiles - 1)
    work_row = jnp.where(real & (col < B), col, -1)
    prev = jnp.concatenate([jnp.full((1,), -1, i32), work_tile[:-1]])
    nxt = jnp.concatenate([work_tile[1:], jnp.full((1,), -1, i32)])
    work_first = (work_tile != prev).astype(i32)
    work_last = (work_tile != nxt).astype(i32)
    return work_tile, work_row, work_first, work_last, row_start


def _ragged_kernel(pt_ref, wt_ref, wr_ref, wf_ref, wl_ref, rs_ref,
                   qs_ref, ql_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, ks_ref, vs_ref,
                   bs: int, TQ: int, KV: int, G: int, scale: float,
                   window, np_: int):
    w = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((wf_ref[w] == 1) & (j == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    wt = wt_ref[w]
    wr = wr_ref[w]
    row = jnp.maximum(wr, 0)
    start = rs_ref[row]                # row's first flat slot
    qstart = qs_ref[row]               # abs position of that slot's query
    qlen = ql_ref[row]
    lo = jnp.maximum(start, wt * TQ)   # row ∩ tile flat range
    hi = jnp.minimum(start + qlen, wt * TQ + TQ)
    last_pq = qstart + (hi - 1 - start)    # abs pos of last in-tile query
    live = (wr >= 0) & (j * bs <= last_pq)
    if window is not None:
        # first in-tile query's window lower bound; later queries see more
        first_pq = qstart + (lo - start)
        live &= j * bs + bs - 1 > first_pq - window

    @pl.when(live)
    def _accumulate():
        # flat slot / key position masks are head-independent
        shape = (TQ * G, bs)
        ti = jax.lax.broadcasted_iota(jnp.int32, shape, 0) // G
        tt = wt * TQ + ti                              # flat slot index
        own = (tt >= start) & (tt < start + qlen)
        pq = qstart + (tt - start)                     # abs query positions
        t = j * bs + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
        mask = own & (t <= pq)
        if window is not None:
            mask &= t > pq - window

        for h in range(KV):            # static unroll: plain 2D dots
            q = q_ref[:, h].astype(jnp.float32).reshape(TQ * G, -1)
            k = k_ref[0, :, h].astype(jnp.float32)     # [bs, hd]
            v = v_ref[0, :, h].astype(jnp.float32)     # [bs, hd]
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
            if ks_ref is not None:
                s = s * ks_ref[0, :, h][None, :]       # fused k dequant
            s = jnp.where(mask, s, _NEG)

            m_old = m_ref[h]
            m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
            corr = jnp.exp(m_old - m_new)
            e = jnp.exp(s - m_new[:, None])
            e = jnp.where(mask, e, 0.0)    # fully-masked rows: e would be 1
            l_ref[h] = l_ref[h] * corr + jnp.sum(e, axis=1)
            if vs_ref is not None:
                e = e * vs_ref[0, :, h][None, :]       # fused v dequant
            acc_ref[h] = acc_ref[h] * corr[:, None] + jnp.dot(
                e, v, preferred_element_type=jnp.float32)
            m_ref[h] = m_new

    @pl.when((wl_ref[w] == 1) & (j == np_ - 1))
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[...] = (acc_ref[...] / denom).reshape(
            KV, TQ, G, o_ref.shape[-1]).transpose(1, 0, 2, 3).astype(
                o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "tile_q", "interpret"))
def ragged_attention(q, k_pages, v_pages, page_table, q_start, q_len,
                     *, k_scale=None, v_scale=None, window=None,
                     tile_q: int = 16, interpret: bool = False):
    """One ragged flat-token mixed step over a block-paged KV pool.

    q           [W, KV, G, hd]    flat token-batch queries: row b's
                                  tokens at slots [row_start[b],
                                  row_start[b] + q_len[b]); the tail
                                  past sum(q_len) is bucket padding
    k_pages     [N, bs, KV, hd]   shared KV block pool (f32/bf16 or int8)
    v_pages     [N, bs, KV, hd]
    page_table  [B, P] int32      block id of page j of row b (0 = null)
    q_start     [B]    int32      absolute position of the row's first
                                  query this tick
    q_len       [B]    int32      live queries this tick, any value in
                                  [0, C] (0 = idle row, no flat slots)
    k_scale     [N, bs, KV] f32   per-token dequant scales (int8 pool)
    v_scale     [N, bs, KV] f32
    window      sliding-window size (None = full causal)
    tile_q      flat tokens per grid tile (clamped to W; W must divide
                evenly by the clamped value)

    Every live query's own key must be scattered into the pool before
    the call.  Padding slots (flat index >= sum(q_len)) output zeros.
    Returns [W, KV, G, hd] in q's dtype.
    """
    W, KV, G, hd = q.shape
    B, P = page_table.shape
    bs = k_pages.shape[1]
    TQ = min(tile_q, W)
    if W % TQ:
        raise ValueError(f"flat width {W} not a multiple of tile_q {TQ}")
    nt = W // TQ
    scale = 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    wt, wr, wf, wl, row_start = flat_work_layout(q_len, nt, TQ)

    def idx_q(w, j, pt, wt, wr, wf, wl, rs, qs, ql):
        return (wt[w], 0, 0, 0)

    def idx_kv(w, j, pt, wt, wr, wf, wl, rs, qs, ql):
        return (pt[jnp.maximum(wr[w], 0), j], 0, 0, 0)

    def idx_sc(w, j, pt, wt, wr, wf, wl, rs, qs, ql):
        return (pt[jnp.maximum(wr[w], 0), j], 0, 0)

    in_specs = [
        pl.BlockSpec((TQ, KV, G, hd), idx_q),
        pl.BlockSpec((1, bs, KV, hd), idx_kv),
        pl.BlockSpec((1, bs, KV, hd), idx_kv),
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, KV), idx_sc),
                     pl.BlockSpec((1, bs, KV), idx_sc)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _ragged_kernel, bs=bs, TQ=TQ, KV=KV, G=G, scale=scale,
        window=window, np_=P)

    def body(pt_ref, wt_ref, wr_ref, wf_ref, wl_ref, rs_ref, qs_ref,
             ql_ref, *rest):
        if quant:
            (q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
             acc_ref, m_ref, l_ref) = rest
        else:
            q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = rest
            ks_ref = vs_ref = None
        kernel(pt_ref, wt_ref, wr_ref, wf_ref, wl_ref, rs_ref, qs_ref,
               ql_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
               l_ref, ks_ref=ks_ref, vs_ref=vs_ref)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=8,
        grid=(nt + B, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((TQ, KV, G, hd), idx_q),
        scratch_shapes=[
            pltpu.VMEM((KV, TQ * G, hd), jnp.float32),   # acc
            pltpu.VMEM((KV, TQ * G), jnp.float32),       # running max m
            pltpu.VMEM((KV, TQ * G), jnp.float32),       # running Σexp l
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((W, KV, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, wt, wr, wf, wl, row_start,
      q_start.astype(jnp.int32), q_len.astype(jnp.int32), *operands)
