"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container (kernel bodies execute in Python) and compile to Mosaic on
real TPU.  Model code opts in via config/env; the jnp paths in
repro.models.blocks remain the default substrate.
"""
from __future__ import annotations

import jax

from repro.kernels.confidence_gate import confidence_gate as _gate
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.mixed_attention import mixed_attention as _mixed
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.ragged_attention import ragged_attention as _ragged
from repro.kernels.prefill_attention import \
    paged_prefill_attention as _paged_prefill
from repro.kernels.router_gate import router_gate as _router
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def confidence_gate(logits, *, interpret=None):
    return _gate(logits, interpret=_default_interpret()
                 if interpret is None else interpret)


def flash_attention(q, k, v, *, causal=True, window=None, interpret=None):
    return _flash(q, k, v, causal=causal, window=window,
                  interpret=_default_interpret()
                  if interpret is None else interpret)


def paged_attention(q, k_pages, v_pages, page_table, pos, *,
                    k_scale=None, v_scale=None, window=None, interpret=None):
    return _paged(q, k_pages, v_pages, page_table, pos,
                  k_scale=k_scale, v_scale=v_scale, window=window,
                  interpret=_default_interpret()
                  if interpret is None else interpret)


def paged_prefill_attention(q, k_pages, v_pages, page_table, q_start, q_len,
                            *, k_scale=None, v_scale=None, window=None,
                            interpret=None):
    return _paged_prefill(q, k_pages, v_pages, page_table, q_start, q_len,
                          k_scale=k_scale, v_scale=v_scale, window=window,
                          interpret=_default_interpret()
                          if interpret is None else interpret)


def mixed_attention(q, k_pages, v_pages, page_table, q_start, q_len, *,
                    k_scale=None, v_scale=None, window=None,
                    interpret=None):
    return _mixed(q, k_pages, v_pages, page_table, q_start, q_len,
                  k_scale=k_scale, v_scale=v_scale, window=window,
                  interpret=_default_interpret()
                  if interpret is None else interpret)


def ragged_attention(q, k_pages, v_pages, page_table, q_start, q_len, *,
                     k_scale=None, v_scale=None, window=None,
                     tile_q=16, interpret=None):
    return _ragged(q, k_pages, v_pages, page_table, q_start, q_len,
                   k_scale=k_scale, v_scale=v_scale, window=window,
                   tile_q=tile_q,
                   interpret=_default_interpret()
                   if interpret is None else interpret)


def rwkv6_scan(r, k, v, w, u, *, interpret=None):
    return _rwkv(r, k, v, w, u, interpret=_default_interpret()
                 if interpret is None else interpret)


def mamba_scan(x, dt, B_t, C_t, A, *, interpret=None):
    return _mamba(x, dt, B_t, C_t, A, interpret=_default_interpret()
                  if interpret is None else interpret)


def router_gate(logits, k, *, interpret=None):
    return _router(logits, k, interpret=_default_interpret()
                   if interpret is None else interpret)
