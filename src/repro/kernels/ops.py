"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container (kernel bodies execute in Python) and compile to Mosaic on
real TPU.  Model code opts in via config/env; the jnp paths in
repro.models.blocks remain the default substrate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.confidence_gate import confidence_gate as _gate
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.mixed_attention import mixed_attention as _mixed
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.ragged_attention import ragged_attention as _ragged
from repro.kernels.prefill_attention import \
    paged_prefill_attention as _paged_prefill
from repro.kernels.router_gate import router_gate as _router
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def confidence_gate(logits, *, interpret=None):
    return _gate(logits, interpret=_default_interpret()
                 if interpret is None else interpret)


def spec_accept(argmax_w, conf_w, q_len, flat_tokens, k):
    """Fused accept/reject epilogue for speculative cascade verify.

    Consumes the per-position picks of a flat verify pass — ``argmax_w``
    / ``conf_w`` shaped [W], the per-flat-slot argmax token and
    max-softmax-prob confidence (from :func:`confidence_gate` over the
    ``[W, V]`` logits of ``transformer.ragged_verify``, or the jnp
    fallback) — plus the ragged layout (``q_len [R]``, the launch's
    ``flat_tokens [1, W]``) and the static draft bound ``k``, and
    decides acceptance device-side so the engine still pays ONE
    ``device_get`` per tier per tick:

    * ``tok``/``conf`` [R] — each row's last-live-slot pick, the exact
      contract of the non-speculative ragged step (the gate is
      per-position, so gating all W slots then gathering equals
      gathering then gating).
    * ``spec_tok``/``spec_conf`` [R, k+1] — the row's window of picks
      starting at its first flat slot: position j is the scoring model's
      argmax after consuming drafted token j (j=0 consumes the row's
      last emitted token).
    * ``acc_len`` [R] — accepted draft count: the longest prefix where
      slot j's argmax equals the *next* drafted token in the flat batch
      (``flat_tokens[start + j + 1]``), greedy speculative decoding's
      acceptance rule.  Rows with ``q_len <= 1`` (no drafts) get 0.

    Emitted tokens are always ``spec_tok[:acc_len + 1]`` — scoring-model
    argmaxes, never drafts — so streams are bit-identical to the
    non-speculative oracle at any k.
    """
    w = argmax_w.shape[0]
    csum = jnp.cumsum(q_len)
    last = jnp.clip(csum - 1, 0, w - 1)
    start = csum - q_len
    idx = start[:, None] + jnp.arange(k + 1, dtype=q_len.dtype)[None, :]
    spec_tok = argmax_w[jnp.clip(idx, 0, w - 1)].astype(jnp.int32)
    spec_conf = conf_w[jnp.clip(idx, 0, w - 1)]
    drafted = flat_tokens[0][jnp.clip(idx + 1, 0, w - 1)]
    valid = jnp.arange(k + 1)[None, :] < (q_len - 1)[:, None]
    match = (spec_tok == drafted) & valid
    acc_len = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    return {"tok": argmax_w[last].astype(jnp.int32), "conf": conf_w[last],
            "spec_tok": spec_tok, "spec_conf": spec_conf,
            "acc_len": acc_len}


def flash_attention(q, k, v, *, causal=True, window=None, interpret=None):
    return _flash(q, k, v, causal=causal, window=window,
                  interpret=_default_interpret()
                  if interpret is None else interpret)


def paged_attention(q, k_pages, v_pages, page_table, pos, *,
                    k_scale=None, v_scale=None, window=None, interpret=None):
    return _paged(q, k_pages, v_pages, page_table, pos,
                  k_scale=k_scale, v_scale=v_scale, window=window,
                  interpret=_default_interpret()
                  if interpret is None else interpret)


def paged_prefill_attention(q, k_pages, v_pages, page_table, q_start, q_len,
                            *, k_scale=None, v_scale=None, window=None,
                            interpret=None):
    return _paged_prefill(q, k_pages, v_pages, page_table, q_start, q_len,
                          k_scale=k_scale, v_scale=v_scale, window=window,
                          interpret=_default_interpret()
                          if interpret is None else interpret)


def mixed_attention(q, k_pages, v_pages, page_table, q_start, q_len, *,
                    k_scale=None, v_scale=None, window=None,
                    interpret=None):
    return _mixed(q, k_pages, v_pages, page_table, q_start, q_len,
                  k_scale=k_scale, v_scale=v_scale, window=window,
                  interpret=_default_interpret()
                  if interpret is None else interpret)


def ragged_attention(q, k_pages, v_pages, page_table, q_start, q_len, *,
                     k_scale=None, v_scale=None, window=None,
                     tile_q=16, interpret=None):
    return _ragged(q, k_pages, v_pages, page_table, q_start, q_len,
                   k_scale=k_scale, v_scale=v_scale, window=window,
                   tile_q=tile_q,
                   interpret=_default_interpret()
                   if interpret is None else interpret)


def rwkv6_scan(r, k, v, w, u, *, interpret=None):
    return _rwkv(r, k, v, w, u, interpret=_default_interpret()
                 if interpret is None else interpret)


def mamba_scan(x, dt, B_t, C_t, A, *, interpret=None):
    return _mamba(x, dt, B_t, C_t, A, interpret=_default_interpret()
                  if interpret is None else interpret)


def router_gate(logits, k, *, interpret=None):
    return _router(logits, k, interpret=_default_interpret()
                   if interpret is None else interpret)
