"""Guarded activation-sharding hints.

``shard_hint(x, 'batch', None, 'model')`` applies a
with_sharding_constraint iff a mesh is active (jax.set_mesh) — model code
stays mesh-agnostic and runs unannotated on a single device (smoke tests),
while under the production mesh GSPMD gets the constraints it cannot
infer (the MoE dispatch one-hot chain replicates without them: measured
~490 GB/chip of temp on the kimi-k2 train dry-run, vs ~11 GB with hints).

Logical names: 'batch' -> ('pod','data') axes present in the mesh;
'model' -> 'model'; None -> unsharded.  A dim is only constrained when its
size divides the axis total (uneven dims are left to GSPMD).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec


def _axis_total(mesh, names):
    return math.prod(dict(mesh.shape)[n] for n in names) if names else 1


def data_axis_size(mesh) -> int:
    """Total data parallelism of ``mesh``: the product of its 'pod' and
    'data' axis sizes (1 for no mesh or a model-only mesh).  The serving
    engine partitions each tier's request rows and KV block pool into
    this many shards."""
    if mesh is None:
        return 1
    sizes = dict(mesh.shape)
    return _axis_total(mesh, [a for a in ("pod", "data") if a in sizes])


def active_mesh():
    """The mesh activated by :func:`set_mesh`, across jax versions:
    ``jax.sharding.get_abstract_mesh`` (jax >= 0.5) or the ``with mesh:``
    thread-resource context (0.4.x)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax._src import mesh as mesh_lib
    pm = mesh_lib.thread_resources.env.physical_mesh
    return None if pm.empty else pm


def set_mesh(mesh):
    """Context manager activating ``mesh`` (``jax.set_mesh`` on jax >= 0.5;
    on 0.4.x a ``Mesh`` is itself the context manager)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_seq_if_heads_unshardable(x, num_heads: int):
    """x [B, T, KV, hd]: shard T over 'model' ONLY when the head dim
    cannot absorb the model axis (kv % model != 0).  With shardable heads
    the default head-parallel layout is already collective-free; forcing a
    T-shard there would just add resharding."""
    mesh = active_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    m = dict(mesh.shape).get("model", 1)
    if m <= 1 or num_heads % m == 0:
        return x
    return shard_hint(x, "batch", "model", None, None)


def shard_hint(x, *spec):
    mesh = active_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    sizes = dict(mesh.shape)
    resolved = []
    for dim, s in zip(x.shape, spec):
        if s == "batch":
            axes = tuple(a for a in ("pod", "data") if a in sizes)
            total = _axis_total(mesh, axes)
            if axes and total > 1 and dim % total == 0:
                resolved.append(axes if len(axes) > 1 else axes[0])
            else:
                resolved.append(None)
        elif s == "model":
            if "model" in sizes and sizes["model"] > 1 \
                    and dim % sizes["model"] == 0:
                resolved.append("model")
            else:
                resolved.append(None)
        else:
            resolved.append(None)
    if all(r is None for r in resolved):
        return x
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*resolved))
