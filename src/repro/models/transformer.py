"""Model stack: embeddings -> head layers -> scanned periods -> tail -> head.

The repeated ``period`` runs under ``jax.lax.scan`` over weights (and cache)
stacked on a leading ``num_periods`` dim, keeping the lowered HLO small for
deep models.  ``cfg.remat`` wraps the period body in ``jax.checkpoint``.

Entry points:

  * :func:`forward`     — logits for a full sequence (train) or with cache
                          population (prefill) or one-token decode.
  * :func:`train_logits`— convenience wrapper returning (logits, aux).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import blocks


def _add_aux(a, b):
    return {k: a[k] + b[k] for k in a}


def _apply_unrolled(params, cfg, layers, x, cache, pos, mode, aux,
                    pages=None):
    new_cache = {}
    for i, layer in enumerate(layers):
        key = f"layer{i}"
        c = cache[key] if cache is not None else None
        x, nc, a = blocks.apply_layer(params[key], cfg, layer, x, c, pos,
                                      mode, pages=pages)
        aux = _add_aux(aux, a)
        if nc is not None:
            new_cache[key] = nc
    return x, (new_cache or None), aux


def _apply_periods(params, cfg: ModelConfig, x, cache, pos, mode, aux,
                   collect_exits: bool = False, pages=None):
    """Scan over the stacked period weights (+cache).  ``pages`` is
    loop-invariant (one page table for all layers) and enters the scan
    body by closure."""

    def body(carry, xs):
        xc, aux_c = carry
        p_slice, c_slice = xs
        nc = {}
        for i, layer in enumerate(cfg.period):
            key = f"block{i}"
            c = c_slice[key] if c_slice is not None else None
            xc, ci, a = blocks.apply_layer(p_slice[key], cfg, layer, xc, c,
                                           pos, mode, pages=pages)
            aux_c = _add_aux(aux_c, a)
            if ci is not None:
                nc[key] = ci
        ys = {}
        if nc:
            ys["cache"] = nc
        if collect_exits:
            ys["hidden"] = xc
        return (xc, aux_c), ys

    if cfg.remat:
        body = jax.checkpoint(body)

    if cfg.unroll_periods:
        # python loop (exact per-trip cost in HLO — used by the dry-run's
        # scan-cost correction; see launch.dryrun)
        carry = (x, aux)
        ys_list = []
        for i in range(cfg.num_periods):
            p_i = jax.tree.map(lambda a: a[i], params["period"])
            c_i = jax.tree.map(lambda a: a[i], cache) if cache is not None \
                else None
            carry, ys_i = body(carry, (p_i, c_i))
            ys_list.append(ys_i)
        x, aux = carry
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys_list) if ys_list and \
            ys_list[0] else {}
    else:
        xs = (params["period"], cache)
        (x, aux), ys = lax.scan(body, (x, aux), xs)
    new_cache = ys.get("cache")
    exits = ys.get("hidden")           # [num_periods, B, S, D] if collected
    return x, new_cache, aux, exits


def _logits(params, cfg: ModelConfig, x):
    x = blocks.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def _embed(params, cfg: ModelConfig, batch, mode):
    tokens = batch["tokens"]
    x = params["embed"][tokens]  # gather; vocab-sharded -> GSPMD collective
    if cfg.frontend and mode in ("prefill_chunk", "mixed_step",
                                 "ragged_step"):
        raise NotImplementedError(
            "chunked/unified token-batch steps do not inject modality "
            "frontend embeddings; frontend models require the dense "
            "uniform prefill path")
    if cfg.frontend and mode != "decode":
        # sanctioned modality stub: precomputed frame/patch embeddings are
        # projected into d_model and replace the first frontend_len slots.
        emb = batch["frontend_embeds"] @ params["frontend_proj"]
        fl = cfg.frontend_len
        pad = x.shape[1] - fl
        emb_full = jnp.pad(emb.astype(x.dtype), ((0, 0), (0, pad), (0, 0)))
        is_front = (jnp.arange(x.shape[1]) < fl)[None, :, None]
        x = jnp.where(is_front, emb_full, x)
    return x


def lm_proj(params, cfg: ModelConfig):
    """The output projection matrix [D, V] (tied or separate)."""
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params, cfg: ModelConfig, batch: dict, *, mode: str = "train",
            cache=None, pos=None, return_hidden: bool = False, pages=None):
    """Returns (logits, new_cache, aux) — or, with ``return_hidden``,
    (final-norm hidden states, new_cache, aux) so the caller can apply
    the LM head itself (seq-chunked CE, repro.core.losses.chunked_lm_loss).

    batch: {"tokens": [B,S] int32, optional "frontend_embeds": [B,fl,fd]}
    pos:   [B,S] absolute positions (defaults to arange for train/prefill;
           required for decode, prefill_chunk, mixed_step, and
           ragged_step).
    pages: ``{"page_table": [B, P] int32}`` selects the block-paged KV
           layout (cache from ``init_paged_cache``); decode,
           prefill_chunk, mixed_step, and ragged_step.
           prefill_chunk/mixed_step additionally need
           ``"q_len": [B] int32`` (live tokens per row this step) and
           per-row positions in ``pos``; ragged_step takes a flat
           ``[1, W]`` token batch with ``"q_start": [R]`` per-row first
           positions — see :func:`repro.models.blocks.attention`.
    """
    x = _embed(params, cfg, batch, mode)
    B, S = batch["tokens"].shape
    if pos is None:
        if mode in ("decode", "prefill_chunk", "mixed_step",
                    "ragged_step"):
            raise ValueError(f"{mode} requires pos")
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32)}
    new_cache = {}

    if cfg.head:
        c = cache.get("head") if cache else None
        x, nc, aux = _apply_unrolled(params["head"], cfg, cfg.head, x, c, pos,
                                     mode, aux, pages=pages)
        if nc:
            new_cache["head"] = nc

    exits = None
    if cfg.num_periods:
        c = cache.get("period") if cache else None
        collect = bool(cfg.early_exit_periods) and mode not in (
            "decode", "prefill_chunk", "mixed_step", "ragged_step")
        x, nc, aux, exits = _apply_periods(params, cfg, x, c, pos, mode, aux,
                                           collect_exits=collect, pages=pages)
        if nc is not None:
            new_cache["period"] = nc

    if cfg.tail:
        c = cache.get("tail") if cache else None
        x, nc, aux = _apply_unrolled(params["tail"], cfg, cfg.tail, x, c, pos,
                                     mode, aux, pages=pages)
        if nc:
            new_cache["tail"] = nc

    if return_hidden:
        logits = blocks.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    else:
        logits = _logits(params, cfg, x)

    if exits is not None and cfg.early_exit_periods:
        aux = dict(aux)
        aux["exit_logits"] = tuple(
            _exit_logits(params["exit_heads"][f"exit{i}"], cfg, exits[i])
            for i in cfg.early_exit_periods)

    return logits, (new_cache or None), aux


def _exit_logits(p, cfg, h):
    h = blocks.rmsnorm(h, p["norm"], cfg.norm_eps)
    return h @ p["proj"]


def train_logits(params, cfg: ModelConfig, batch):
    logits, _, aux = forward(params, cfg, batch, mode="train")
    return logits, aux


def prefill(params, cfg: ModelConfig, batch, pos=None):
    return forward(params, cfg, batch, mode="prefill", pos=pos)


def prefill_chunk(params, cfg: ModelConfig, tokens, cache, pos, pages):
    """One chunked-prefill step: tokens [B,C] int32 (row b's chunk, padded
    past ``pages['q_len'][b]``); pos [B,C] per-row absolute positions;
    pages {"page_table": [B,P], "q_len": [B]} over a block-paged cache.
    Writes the chunk's KV through the page tables and returns
    (logits [B,C,V], new_cache); logits past a row's q_len are
    unspecified (the engine reads position q_len-1 of the final chunk)."""
    logits, new_cache, _ = forward(params, cfg, {"tokens": tokens},
                                   mode="prefill_chunk", cache=cache,
                                   pos=pos, pages=pages)
    return logits, new_cache


def _token_batch_forward(params, cfg: ModelConfig, tokens, cache, pos,
                         pages, mode):
    """Shared core of the unified token-batch steps (`mixed_step`,
    `ragged_step`, `ragged_verify`): run :func:`forward` in ``mode``
    over a block-paged cache and return the per-position logits plus
    the cache-return contract — the caller picks which positions to
    keep."""
    logits, new_cache, _ = forward(params, cfg, {"tokens": tokens},
                                   mode=mode, cache=cache, pos=pos,
                                   pages=pages)
    return logits, new_cache


def last_slot_gather(logits, q_len, *, flat: bool):
    """Gather each engine row's logits at its last live slot — the one
    last-position contract both unified backends share.

    ``flat=False``: logits [B,C,V], row b's slots are its own row's
    ``[0, q_len[b])`` — last live slot is ``q_len - 1`` (clamped to 0).
    ``flat=True``: logits [1,W,V], row b owns flat slots
    ``[row_start[b], row_start[b] + q_len[b])`` (row_start = exclusive
    prefix sum of q_len) — last live slot is ``cumsum(q_len) - 1``,
    clipped into the flat width.  Rows with ``q_len == 0`` gather
    unspecified logits in both layouts; callers discard them.
    """
    if flat:
        csum = jnp.cumsum(q_len)
        last = jnp.clip(csum - 1, 0, logits.shape[1] - 1)
        return logits[0, last]
    rows = jnp.arange(logits.shape[0])
    last = jnp.maximum(q_len - 1, 0)
    return logits[rows, last]


def mixed_step(params, cfg: ModelConfig, tokens, cache, pos, pages):
    """One unified mixed prefill+decode token-batch step.

    tokens [B,C] int32 — row b's token slots for this tick: its next
    prefill chunk, its single decode token in slot 0, or padding (rows
    stalled/idle this tick); ``pages['q_len'][b]`` live slots each.
    pos [B,C] per-row absolute positions (slot 0 = chunk start / decode
    position); pages {"page_table": [B,P], "q_len": [B]} over a
    block-paged cache.  Scatters every live slot's KV — prefill-chunk
    writes and the decode token's write — through the page tables in one
    program (:func:`repro.models.blocks.attention` mode="mixed_step",
    attention via ``kernels/mixed_attention.py``) and returns
    (last_logits [B,V], new_cache): each row's logits at its last live
    position ``q_len - 1`` — the next-token logits the engine's
    confidence gate consumes (a final prefill chunk's first generated
    token, or a decode row's next token).  ``q_len == 0`` rows return
    unspecified logits; the engine discards them.
    """
    logits, new_cache = _token_batch_forward(params, cfg, tokens, cache,
                                             pos, pages, "mixed_step")
    return last_slot_gather(logits, pages["q_len"], flat=False), new_cache


def ragged_step(params, cfg: ModelConfig, tokens, cache, pos, pages):
    """One ragged flat token-batch prefill+decode step (O(live tokens)).

    tokens [1, W] int32 — the tick's live tokens packed contiguously:
    engine row b's ``pages['q_len'][b]`` tokens occupy flat slots
    ``[row_start[b], row_start[b] + q_len[b])`` (row_start = exclusive
    prefix sum of q_len over engine rows), the tail past ``sum(q_len)``
    is bucket padding.  pos [1, W] per-token absolute positions; pages
    {"page_table": [R, P], "q_len": [R], "q_start": [R]} over a
    block-paged cache, where R is the engine row count (slot capacity)
    and ``q_start[b]`` is row b's first absolute position this tick.
    Scatters every live token's KV through its owning row's page table
    and runs the flat flash program
    (:func:`repro.models.blocks.attention` mode="ragged_step",
    attention via ``kernels/ragged_attention.py``), then gathers each
    row's logits at its last live flat slot ``row_start + q_len - 1`` —
    returning (last_logits [R, V], new_cache) in engine-row order, the
    same contract as :func:`mixed_step` (both via
    :func:`last_slot_gather`).  ``q_len == 0`` rows return unspecified
    logits; the engine discards them.
    """
    logits, new_cache = _token_batch_forward(params, cfg, tokens, cache,
                                             pos, pages, "ragged_step")
    return last_slot_gather(logits, pages["q_len"], flat=True), new_cache


def ragged_verify(params, cfg: ModelConfig, tokens, cache, pos, pages):
    """Per-position variant of :func:`ragged_step` for speculative
    cascade verify: the same flat ``[1, W]`` layout, KV-write semantics,
    and pages contract, but the full per-position logits come back —
    ``(logits [1, W, V], new_cache)`` — instead of the last-slot gather,
    so the verify tier can score *every* drafted position of a verify
    row (``q_len = 1 + k`` flat slots) in the one batched launch.  The
    engine's fused accept/reject epilogue
    (:func:`repro.kernels.ops.spec_accept`) consumes the per-position
    argmax/confidence device-side.  Padding slots and ``q_len == 0``
    rows yield unspecified logits; callers discard them.
    """
    return _token_batch_forward(params, cfg, tokens, cache, pos, pages,
                                "ragged_step")


def decode_step(params, cfg: ModelConfig, token, cache, pos, pages=None):
    """token [B,1] int32; pos [B,1] int32 (per-row decode positions:
    rows may sit at different depths, as under continuous batching).
    ``pages={"page_table": [B, P]}`` selects the block-paged KV layout."""
    logits, new_cache, _ = forward(params, cfg, {"tokens": token},
                                   mode="decode", cache=cache, pos=pos,
                                   pages=pages)
    return logits, new_cache
