"""Transformer / SSM / linear-attention building blocks (pure functions).

Every mixer has the signature::

    y, new_cache, = mixer(p, cfg, spec, x, cache, pos, mode, pages=None)

with ``mode in {'train', 'prefill', 'prefill_chunk', 'mixed_step',
'ragged_step', 'decode'}``.  In train mode caches are ignored (``None`` in / ``None``
out); prefill returns a populated cache; decode consumes ``x`` of
seq-len 1 and a cache, and returns the updated cache.  ``pos`` is
``[B, S]`` int32 absolute positions (decode: ``[B, 1]``).  ``pages``
switches attention to the block-paged KV layout:
``{"page_table": [B, P] int32}`` over a cache from
``repro.models.cache.init_paged_cache`` (decode), plus
``"q_len": [B] int32`` live-token counts in prefill_chunk and
mixed_step modes — the serving engine's mixed-length paths.  In
prefill_chunk each live row advances one fixed-size chunk of its prompt
per call; mixed_step is the unified token-batch step where decode rows
additionally ride in the same batch with ``q_len == 1`` (attention
only; recurrent mixers raise, their state cannot be replayed
chunk-wise).  ragged_step is the flat O(live tokens) form of
mixed_step: the batch is one flat ``[1, W]`` token row packed by the
prefix sum of ``q_len``, and ``pages`` additionally carries
``"q_start": [R] int32`` per-engine-row first positions.

Every ffn has the signature ``y, aux = ffn(p, cfg, spec, x, cache, mode)``
where ``aux`` is a dict of auxiliary scalars (MoE load-balance / router
z-loss; zeros elsewhere).  The RWKV channel-mix is the one stateful ffn
(token shift) and uses the cache slot.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.sharding import shard_hint

# Attention q-chunking threshold: above this seq-len, queries are processed
# in chunks via lax.scan to bound the materialized score matrix (the jnp
# stand-in for the Pallas flash kernel; see repro.kernels.flash_attention).
_Q_CHUNK = 1024
_CHUNK_THRESHOLD = 4096

# MoE dispatch group size (tokens per GShard group).
MOE_GROUP_SIZE = 1024


def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------


def _rope_angles(pos, dim, theta):
    """pos [..., S] -> cos/sin [..., S, dim//2] (float32)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = pos.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rot(x, cos, sin):
    """x [..., S, H, d]; cos/sin [..., S, d//2] broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def apply_rope(q, k, pos, cfg: ModelConfig, kind: str, frontend_len: int = 0):
    """kind: 'rope' | 'mrope' | 'none'.  q [B,S,H,d], k [B,S,KV,d], pos [B,S]."""
    if kind == "none":
        return q, k
    d = q.shape[-1]
    if kind == "rope":
        cos, sin = _rope_angles(pos, d, cfg.rope_theta)
        return _apply_rot(q, cos, sin), _apply_rot(k, cos, sin)
    # M-RoPE [arXiv:2409.12191]: head_dim split into (t, h, w) sections.
    # Vision tokens (pos < frontend_len) take a 2D grid position; text
    # tokens use pos for all three sections.
    sec = _mrope_sections(d)
    is_img = pos < frontend_len
    grid = 32  # dry-run patch grid width
    p_t = jnp.where(is_img, 0, pos)
    p_h = jnp.where(is_img, pos // grid, pos)
    p_w = jnp.where(is_img, pos % grid, pos)
    qs, ks = [], []
    off = 0
    for p_sec, n in zip((p_t, p_h, p_w), sec):
        cos, sin = _rope_angles(p_sec, n, cfg.rope_theta)
        qs.append(_apply_rot(q[..., off:off + n], cos, sin))
        ks.append(_apply_rot(k[..., off:off + n], cos, sin))
        off += n
    return jnp.concatenate(qs, axis=-1), jnp.concatenate(ks, axis=-1)


def _mrope_sections(d):
    t = d // 8            # e.g. 16 for d=128
    hw = (d - t) // 2
    return (t, hw, d - t - hw)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------


def _quant_i8(x, eps=1e-8):
    """Symmetric per-(token, head) int8 quantization of [B,S,KV,d]."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + eps
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def _gqa_scores_to_out(q, k, v, mask, seq_hint: bool = False,
                       k_scale=None, v_scale=None):
    """q [B,S,KV,G,d]; k,v [B,T,KV,d]; mask [B,1,1,S,T] or broadcastable.

    seq_hint (full-seq paths): shard the key dim of the scores over the
    model axis — with few KV heads (kv < mesh model size) the head dims
    cannot absorb the model axis and unhinted scores replicate
    (bkgst f32 at 4k seq is the largest training transient).

    k_scale/v_scale [B,T,KV] (int8 KV cache): the per-token dequant scales
    are folded into the score matrix / attention probs so the int8 cache
    feeds the dots directly (one HBM read at 1 byte/element)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    if k.dtype == jnp.int8:
        # the convert fuses into the dot on TPU: the cache is read at
        # 1 byte/element and dequantized in VREGs
        k = k.astype(jnp.bfloat16)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if k_scale is not None:
        scores = scores * k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    if seq_hint:
        scores = shard_hint(scores, "batch", None, None, None, "model")
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    if v_scale is not None:
        probs = probs * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    probs = probs.astype(v.dtype if v.dtype != jnp.int8 else jnp.bfloat16)
    if seq_hint:
        probs = shard_hint(probs, "batch", None, None, None, "model")
    out = jnp.einsum("bkgst,btkd->bskgd", probs,
                     v if v.dtype != jnp.int8 else v.astype(jnp.bfloat16))
    return out


def attention(p, cfg: ModelConfig, spec, x, cache, pos, mode, pages=None):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    q = (x @ p["wq"]).reshape(B, S, KV, G, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    qr, kr = apply_rope(q.reshape(B, S, H, hd), k, pos, cfg, spec.rope,
                        cfg.frontend_len)
    q = qr.reshape(B, S, KV, G, hd)
    k = kr

    if mode in ("prefill_chunk", "mixed_step"):
        # Paged token-batch step: row b's S token slots (absolute
        # positions pos[b], q_len[b] of them live) are scattered straight
        # into the block pool through the page tables, then a causal
        # flash over the live queries attends each row's already-written
        # KV blocks.  In ``prefill_chunk`` mode (the legacy split path)
        # every live row is a prefill chunk and the program is
        # kernels/prefill_attention; in ``mixed_step`` mode (unified
        # token-batch execution) decode rows ride in the same batch with
        # q_len == 1 — their single token is the new decode token, so the
        # scatter is simultaneously the prefill-chunk KV write and the
        # decode token's KV write — and the program is the generalized
        # kernels/mixed_attention.  Rows with q_len == 0 (stalled or
        # idle this tick) have their writes redirected to the reserved
        # null block 0 and their outputs discarded by the engine, so one
        # fixed-shape program serves any mix of per-row kinds, chunk
        # starts, and tail lengths.
        if pages is None:
            raise ValueError(f"{mode} requires pages={{'page_table', "
                             "'q_len'}} over a block-paged cache")
        from repro.kernels import ops as kernel_ops
        attn_kernel = (kernel_ops.mixed_attention if mode == "mixed_step"
                       else kernel_ops.paged_prefill_attention)
        pt = pages["page_table"]                        # [B, P] int32
        q_len = pages["q_len"]                          # [B] int32
        bs = cache["k"].shape[1]
        P = pt.shape[1]
        # token i of row b lands at (page_table[b, pos//bs], pos % bs);
        # padded tail positions (i >= q_len) may point past the row's
        # pages — clamp the page index and redirect the write to block 0
        page = jnp.minimum(pos // bs, P - 1)
        blk = jnp.take_along_axis(pt, page, axis=1)     # [B, C]
        valid = jax.lax.broadcasted_iota(
            jnp.int32, pos.shape, 1) < q_len[:, None]
        blk = jnp.where(valid, blk, 0)
        off = pos % bs
        q_start = pos[:, 0]
        quant = "k_scale" in cache
        if quant:
            kq, ksc = _quant_i8(k)
            vq, vsc = _quant_i8(v)
            ck = cache["k"].at[blk, off].set(kq)
            cv = cache["v"].at[blk, off].set(vq)
            cks = cache["k_scale"].at[blk, off].set(ksc)
            cvs = cache["v_scale"].at[blk, off].set(vsc)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            out = attn_kernel(
                q, ck, cv, pt, q_start, q_len, k_scale=cks, v_scale=cvs,
                window=spec.window)
        else:
            ck = cache["k"].at[blk, off].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[blk, off].set(v.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            out = attn_kernel(
                q, ck, cv, pt, q_start, q_len, window=spec.window)
        y = out.astype(x.dtype).reshape(B, S, H * hd) @ p["wo"]
        return y, new_cache

    if mode == "ragged_step":
        # Ragged flat token-batch step: the batch is ONE flat row of
        # S == W token slots — engine row b's q_len[b] live tokens pack
        # contiguously at flat slots [row_start[b], row_start[b] +
        # q_len[b]) (row_start = exclusive prefix sum of q_len), the
        # tail past sum(q_len) is bucket padding.  Compute is O(live
        # tokens): a decode row contributes one slot, not a chunk-wide
        # stripe.  Each flat token's owning engine row is recovered from
        # the prefix sum (searchsorted over cumsum(q_len)); its KV write
        # scatters through THAT row's page table at the token's absolute
        # position (pos[0, t]), padding tokens to the reserved null
        # block 0; then the flat flash program is
        # kernels/ragged_attention gathering per-row pages via the same
        # prefix-sum work layout.
        if pages is None:
            raise ValueError("ragged_step requires pages={'page_table', "
                             "'q_len', 'q_start'} over a block-paged "
                             "cache")
        from repro.kernels import ops as kernel_ops
        pt = pages["page_table"]                        # [R, P] int32
        q_len = pages["q_len"]                          # [R] int32
        q_start = pages["q_start"]                      # [R] int32
        R, P = pt.shape
        bs = cache["k"].shape[1]
        csum = jnp.cumsum(q_len)
        tok = jnp.arange(S)
        row = jnp.minimum(
            jnp.searchsorted(csum, tok, side="right"), R - 1)
        valid = tok < csum[-1]
        p_tok = pos[0]                                  # [W] abs positions
        page = jnp.minimum(p_tok // bs, P - 1)
        blk = jnp.where(valid, pt[row, page], 0)
        off = p_tok % bs
        quant = "k_scale" in cache
        if quant:
            kq, ksc = _quant_i8(k)
            vq, vsc = _quant_i8(v)
            ck = cache["k"].at[blk, off].set(kq[0])
            cv = cache["v"].at[blk, off].set(vq[0])
            cks = cache["k_scale"].at[blk, off].set(ksc[0])
            cvs = cache["v_scale"].at[blk, off].set(vsc[0])
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            out = kernel_ops.ragged_attention(
                q[0], ck, cv, pt, q_start, q_len, k_scale=cks,
                v_scale=cvs, window=spec.window)
        else:
            ck = cache["k"].at[blk, off].set(k[0].astype(cache["k"].dtype))
            cv = cache["v"].at[blk, off].set(v[0].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            out = kernel_ops.ragged_attention(
                q[0], ck, cv, pt, q_start, q_len, window=spec.window)
        y = out[None].astype(x.dtype).reshape(B, S, H * hd) @ p["wo"]
        return y, new_cache

    if mode == "decode" and pages is not None:
        # Block-paged decode: the KV cache is a shared pool of fixed-size
        # blocks [N, bs, KV, hd]; row b's live tokens are reached through
        # pages["page_table"] [B, P].  The new token's k/v is scattered
        # into (block, offset) derived from the row's position — rows
        # whose page is unmapped hit the reserved null block 0 (their
        # output is discarded by the engine; see serving.slots) — and
        # attention runs in the Pallas paged flash-decode kernel
        # (interpret mode off-TPU).
        from repro.kernels import ops as kernel_ops
        pt = pages["page_table"]                        # [B, P] int32
        bs = cache["k"].shape[1]
        p_row = pos[:, 0]                               # [B]
        blk = pt[jnp.arange(B), p_row // bs]            # [B]
        off = p_row % bs
        quant = "k_scale" in cache
        if quant:
            kq, ksc = _quant_i8(k)
            vq, vsc = _quant_i8(v)
            ck = cache["k"].at[blk, off].set(kq[:, 0])
            cv = cache["v"].at[blk, off].set(vq[:, 0])
            cks = cache["k_scale"].at[blk, off].set(ksc[:, 0])
            cvs = cache["v_scale"].at[blk, off].set(vsc[:, 0])
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            out = kernel_ops.paged_attention(
                q[:, 0], ck, cv, pt, p_row, k_scale=cks, v_scale=cvs,
                window=spec.window)
        else:
            ck = cache["k"].at[blk, off].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[blk, off].set(v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            out = kernel_ops.paged_attention(q[:, 0], ck, cv, pt, p_row,
                                             window=spec.window)
        y = out.astype(x.dtype).reshape(B, S, H * hd) @ p["wo"]
        return y, new_cache

    if mode == "decode":
        # One new token (S == 1) against a fixed-size cache.  Each row
        # carries its own decode position (continuous batching: slots in
        # the serving pool are at different depths), so the cache write is
        # a per-row scatter and the causal mask is per-row.  With a shared
        # position this is numerically identical to the old
        # dynamic_update_slice path.
        rows = jnp.arange(B)
        p_row = pos[:, 0]                               # [B]
        quant = "k_scale" in cache
        if quant:
            kq, ksc = _quant_i8(k)
            vq, vsc = _quant_i8(v)
            ck = cache["k"].at[rows, p_row].set(kq[:, 0])
            cv = cache["v"].at[rows, p_row].set(vq[:, 0])
            cks = cache["k_scale"].at[rows, p_row].set(ksc[:, 0])
            cvs = cache["v_scale"].at[rows, p_row].set(vsc[:, 0])
        else:
            ck = cache["k"].at[rows, p_row].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, p_row].set(
                v[:, 0].astype(cache["v"].dtype))
        T = ck.shape[1]
        idx = jnp.arange(T)[None, None, None, None, :]
        pb = p_row[:, None, None, None, None]           # [B,1,1,1,1]
        mask = idx <= pb
        if spec.window is not None:
            mask &= idx > pb - spec.window
        if quant:
            out = _gqa_scores_to_out(q, ck, cv, mask, k_scale=cks,
                                     v_scale=cvs)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        else:
            out = _gqa_scores_to_out(q, ck, cv, mask)
            new_cache = {"k": ck, "v": cv}
        y = out.reshape(B, S, H * hd) @ p["wo"]
        return y, new_cache

    # full-sequence (train / prefill)
    if cfg.kv_seq_hint:
        # T-shard k/v over 'model' (only when kv heads can't shard it) so
        # the scores/probs contractions stay shard-aligned (partial sums +
        # small out all-reduce) instead of all-gathering the T-sharded
        # probs — measured 130s -> 4.4s collective on starcoder2 train
        # (§Perf iteration 4)
        from repro.models.sharding import shard_seq_if_heads_unshardable
        k = shard_seq_if_heads_unshardable(k, KV)
        v = shard_seq_if_heads_unshardable(v, KV)
    q_pos = pos[:, None, None, :, None]        # [B,1,1,S,1]
    k_pos = pos[:, None, None, None, :]        # [B,1,1,1,S]
    mask = k_pos <= q_pos
    if spec.window is not None:
        mask &= k_pos > q_pos - spec.window

    if S >= _CHUNK_THRESHOLD:
        n = S // _Q_CHUNK
        kp = pos[:, None, None, None, :]                     # [B,1,1,1,S]

        def body(_, qc_qp):
            qc, qp = qc_qp                                   # qp [B,chunk]
            qpb = qp[:, None, None, :, None]                 # [B,1,1,c,1]
            m = kp <= qpb
            if spec.window is not None:
                m &= kp > qpb - spec.window
            # seq_hint here too: without it the per-chunk scores replicate
            # over the model axis (kimi train: 216 GB/chip vs 88 GB with
            # the hint, despite the SPMD resharding-copy warning)
            return None, _gqa_scores_to_out(qc, k, v, m, seq_hint=True)

        qs = q.reshape(B, n, _Q_CHUNK, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
        ps = pos.reshape(B, n, _Q_CHUNK).transpose(1, 0, 2)
        _, outs = lax.scan(body, None, (qs, ps))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, G, hd)
    else:
        out = _gqa_scores_to_out(q, k, v, mask, seq_hint=True)

    y = out.reshape(B, S, H * hd) @ p["wo"]
    new_cache = None
    if mode == "prefill":
        if cfg.kv_quant == "int8":
            kq, ksc = _quant_i8(k)
            vq, vsc = _quant_i8(v)
            new_cache = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
        else:
            new_cache = {"k": k, "v": v}
    return y, new_cache


# --------------------------------------------------------------------------
# Mamba (selective SSM)
# --------------------------------------------------------------------------


def _causal_conv(x, w, b, cache, mode):
    """Depthwise causal conv. x [B,S,d_in], w [d_conv,d_in].  cache holds the
    trailing d_conv-1 inputs for decode."""
    d_conv = w.shape[0]
    if mode == "decode":
        window = jnp.concatenate([cache, x], axis=1)        # [B,d_conv,d]
        y = jnp.einsum("bcd,cd->bd", window.astype(jnp.float32),
                       w.astype(jnp.float32))[:, None]
        new_cache = window[:, 1:]
        return (y + b).astype(x.dtype), new_cache
    pads = [jnp.pad(x, ((0, 0), (d_conv - 1 - i, 0), (0, 0)))[:, :x.shape[1]]
            for i in range(d_conv)]
    y = sum(pads[i].astype(jnp.float32) * w[i].astype(jnp.float32)
            for i in range(d_conv)) + b
    new_cache = None
    if mode == "prefill":
        new_cache = x[:, -(d_conv - 1):].astype(jnp.float32).astype(x.dtype)
    return y.astype(x.dtype), new_cache


def mamba(p, cfg: ModelConfig, spec, x, cache, pos, mode, pages=None):
    if mode in ("prefill_chunk", "mixed_step", "ragged_step"):
        raise NotImplementedError(
            "chunked/unified token-batch steps carry no recurrent state "
            "across chunks; mamba layers require the dense uniform "
            "prefill path")
    B, S, D = x.shape
    d_in = spec.expand * cfg.d_model
    n = spec.d_state
    dt_rank = math.ceil(cfg.d_model / 16)

    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_cache, mode)
    xi = jax.nn.silu(xi)

    proj = xi @ p["x_proj"]                                  # [B,S,r+2n]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"]
                         + p["dt_bias"]).astype(jnp.float32)  # [B,S,d_in]
    Bt = proj[..., dt_rank:dt_rank + n].astype(jnp.float32)   # [B,S,n]
    Ct = proj[..., dt_rank + n:].astype(jnp.float32)          # [B,S,n]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # [d_in,n]
    xf = xi.astype(jnp.float32)

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp                 # [B,d_in],[B,n],[B,n],[B,d_in]
        da = jnp.exp(dt_t[..., None] * A)                       # [B,d_in,n]
        dbx = (dt_t * x_t)[..., None] * B_t[:, None, :]          # [B,d_in,n]
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    if mode == "decode":
        h0 = cache["ssm"].astype(jnp.float32)
        h1, y = step(h0, (dt[:, 0], Bt[:, 0], Ct[:, 0], xf[:, 0]))
        y = y[:, None]
        new_ssm = h1
    else:
        h0 = jnp.zeros((B, d_in, n), jnp.float32)
        xs = (dt.transpose(1, 0, 2), Bt.transpose(1, 0, 2),
              Ct.transpose(1, 0, 2), xf.transpose(1, 0, 2))
        h1, ys = lax.scan(step, h0, xs)
        y = ys.transpose(1, 0, 2)
        new_ssm = h1

    y = y + xf * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]

    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"conv": new_conv, "ssm": new_ssm.astype(jnp.float32)}
    return out, new_cache


# --------------------------------------------------------------------------
# RWKV-6 time mix
# --------------------------------------------------------------------------


def _token_shift(x, x_prev, mode):
    """Returns x_{t-1} per position.  x_prev: [B,1,D] last token of the
    previous segment (zeros at sequence start)."""
    if mode == "decode":
        return x_prev
    shifted = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    return shifted


def rwkv6(p, cfg: ModelConfig, spec, x, cache, pos, mode, pages=None):
    if mode in ("prefill_chunk", "mixed_step", "ragged_step"):
        raise NotImplementedError(
            "chunked/unified token-batch steps carry no recurrent state "
            "across chunks; rwkv6 layers require the dense uniform "
            "prefill path")
    B, S, D = x.shape
    hd = spec.head_dim
    H = D // hd

    x_prev = cache["x_prev"] if cache is not None else None
    xs = _token_shift(x, x_prev, mode)

    def lerp(mix):
        return x + (xs - x) * mix

    r = (lerp(p["mix_r"]) @ p["wr"]).reshape(B, S, H, hd)
    k = (lerp(p["mix_k"]) @ p["wk"]).reshape(B, S, H, hd)
    v = (lerp(p["mix_v"]) @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(lerp(p["mix_g"]) @ p["wg"])
    # data-dependent decay (the Finch contribution): w in (0,1)
    xw = lerp(p["mix_w"])
    w = jnp.exp(-jnp.exp((p["w0"] + jnp.tanh(xw @ p["wA"]) @ p["wB"])
                         .astype(jnp.float32))).reshape(B, S, H, hd)

    u = p["bonus"].astype(jnp.float32)                      # [H,hd]
    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp            # [B,H,hd] each
        kv = k_t[..., :, None] * v_t[..., None, :]          # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[..., None] * kv)
        state = w_t[..., None] * state + kv
        return state, y

    if mode == "decode":
        s0 = cache["state"].astype(jnp.float32)
        s1, y = step(s0, (r32[:, 0], k32[:, 0], v32[:, 0], w[:, 0]))
        y = y[:, None]
    else:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        seq = (r32.transpose(1, 0, 2, 3), k32.transpose(1, 0, 2, 3),
               v32.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
        s1, ys = lax.scan(step, s0, seq)
        y = ys.transpose(1, 0, 2, 3)

    # per-head group norm, then output gate + projection
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mean) * lax.rsqrt(var + 64e-5)
    y = y.reshape(B, S, D) * p["ln_x"].astype(jnp.float32)
    out = (y.astype(x.dtype) * g) @ p["wo"]

    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"x_prev": x[:, -1:], "state": s1}
    return out, new_cache


MIXERS = {"attn": attention, "mamba": mamba, "rwkv6": rwkv6}


# --------------------------------------------------------------------------
# FFNs
# --------------------------------------------------------------------------


def _zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32)}


def dense_ffn(p, cfg: ModelConfig, spec, x, cache, mode):
    if spec.act == "rwkv_cmix":
        if mode in ("prefill_chunk", "mixed_step", "ragged_step"):
            raise NotImplementedError(
                "chunked/unified token-batch steps carry no token-shift "
                "state across chunks; rwkv_cmix ffns require the dense "
                "prefill path")
        x_prev = cache["x_prev"] if cache is not None else None
        xs = _token_shift(x, x_prev, mode)
        xk = x + (xs - x) * p["mix_k"]
        xr = x + (xs - x) * p["mix_r"]
        k = jnp.square(jax.nn.relu(xk @ p["wk"]))
        out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
        new_cache = {"x_prev": x[:, -1:]} if mode in ("decode", "prefill") else None
        return out, new_cache, _zero_aux()
    if spec.act == "swiglu":
        h = jax.nn.silu(x @ p["wi0"]) * (x @ p["wi1"])
    else:  # gelu
        h = jax.nn.gelu(x @ p["wi"])
    return h @ p["wo"], None, _zero_aux()


def moe_ffn(p, cfg: ModelConfig, spec, x, cache, mode):
    """GShard-style token-choice top-k MoE with einsum dispatch.

    Tokens are split into groups of MOE_GROUP_SIZE (groups align with data
    shards); each expert takes at most ``capacity`` tokens per group,
    overflow is dropped (residual passes through).
    """
    B, S, D = x.shape
    E, K = spec.num_experts, spec.top_k
    N = B * S
    gs = min(MOE_GROUP_SIZE, N)
    G = N // gs
    xg = shard_hint(x.reshape(G, gs, D), "batch", None, None)

    logits = (xg @ p["router"]).astype(jnp.float32)          # [G,s,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = lax.top_k(probs, K)                     # [G,s,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    cap = max(1, int(math.ceil(gs * K * spec.capacity_factor / E)))
    cap = min(cap, gs)

    sel = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # [G,s,K,E]
    sel = shard_hint(sel, "batch", None, None, "model")
    # position of each (token, k) within its expert queue, in (s, k) order
    flat = sel.reshape(G, gs * K, E)
    ranks = (jnp.cumsum(flat, axis=1) - flat).reshape(G, gs, K, E)
    keep = ranks < cap
    sel = sel * keep
    slot = jnp.einsum("gske,gske->gsk", ranks, sel)          # rank of chosen
    slot_oh = jax.nn.one_hot(slot, cap, dtype=jnp.float32) * \
        jnp.sum(sel, -1, keepdims=True)                      # [G,s,K,C]
    dispatch = jnp.einsum("gske,gskc->gsec", sel, slot_oh)   # [G,s,E,C]
    dispatch = shard_hint(dispatch, "batch", None, "model", None)
    combine = jnp.einsum("gsk,gske,gskc->gsec", gate_vals, sel, slot_oh)
    combine = shard_hint(combine, "batch", None, "model", None)

    xin = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xg)
    xin = shard_hint(xin, "model", "batch", None, None)
    if spec.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, p["wi0"])) * \
            jnp.einsum("egcd,edf->egcf", xin, p["wi1"])
    else:
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", xin, p["wi"]))
    h = shard_hint(h, "model", "batch", None, None)
    eout = jnp.einsum("egcf,efd->egcd", h, p["wo"])
    eout = shard_hint(eout, "model", "batch", None, None)
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), eout)
    out = shard_hint(out, "batch", None, None)

    # aux losses (Switch Transformer): load balance + router z-loss
    me = jnp.mean(probs, axis=(0, 1))                        # [E]
    ce = jnp.mean(jnp.sum(sel, axis=2), axis=(0, 1))         # fraction routed
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return out.reshape(B, S, D), None, {"lb_loss": lb, "z_loss": z}


def apply_ffn(p, cfg, spec, x, cache, mode):
    if spec.kind == "moe":
        return moe_ffn(p, cfg, spec, x, cache, mode)
    return dense_ffn(p, cfg, spec, x, cache, mode)


# --------------------------------------------------------------------------
# Layer
# --------------------------------------------------------------------------


def apply_layer(p, cfg: ModelConfig, layer, x, cache, pos, mode, pages=None):
    """Pre-norm residual layer: x + mixer(norm(x)); x + ffn(norm(x))."""
    mix_cache = cache.get("mixer") if cache else None
    ffn_cache = cache.get("ffn") if cache else None

    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    y, new_mix = MIXERS[layer.mixer.kind](p["mixer"], cfg, layer.mixer, h,
                                          mix_cache, pos, mode, pages=pages)
    x = x + y
    h = rmsnorm(x, p["norm2"], cfg.norm_eps)
    y, new_ffn, aux = apply_ffn(p["ffn"], cfg, layer.ffn, h, ffn_cache, mode)
    x = x + y

    new_cache = None
    if mode in ("decode", "prefill", "prefill_chunk", "mixed_step",
                "ragged_step"):
        new_cache = {"mixer": new_mix if new_mix is not None else {},
                     "ffn": new_ffn if new_ffn is not None else {}}
    return x, new_cache, aux
