"""Parameter declaration framework.

Every block declares its parameters as a pytree of :class:`P` (shape +
logical axes + init).  From one declaration we derive:

  * ``init_params``  — materialized arrays (smoke tests, real training)
  * ``param_shapes`` — ShapeDtypeStructs (dry-run lowering, no allocation)
  * ``param_specs``  — PartitionSpecs for a concrete mesh (GSPMD sharding)

Sharding follows MaxText-style logical-axis rules: the ``model`` mesh axis
is greedily placed on the highest-priority divisible dim of each tensor
(experts > vocab > ffn/fused-heads > d_inner), and when ``cfg.fsdp`` the
``data`` axis is additionally placed on a remaining divisible ``d_model``
dim (2D / ZeRO-style weight sharding).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig


class P(NamedTuple):
    shape: tuple
    axes: tuple            # logical axis name per dim (or None)
    init: str = "fan_in"   # fan_in | zeros | ones | normal:<s> | mamba_A | mamba_dt


# priority of logical axes for the `model` mesh axis
_MODEL_PRIORITY = ("experts", "vocab", "ffn", "fused_heads", "d_inner", "frontend")
# axes eligible for the `data` mesh axis under fsdp
_FSDP_AXES = ("d_model", "ffn2")


def logical_to_spec(p: P, mesh, fsdp: bool) -> PartitionSpec:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    data = sizes.get("data", 1)
    spec = [None] * len(p.shape)
    for target in _MODEL_PRIORITY:
        hit = False
        for i, (a, s) in enumerate(zip(p.axes, p.shape)):
            if a == target and s % model == 0 and model > 1:
                spec[i] = "model"
                hit = True
                break
        if hit:
            break
    if fsdp and data > 1:
        for i, (a, s) in enumerate(zip(p.axes, p.shape)):
            if a in _FSDP_AXES and spec[i] is None and s % data == 0:
                spec[i] = "data"
                break
    return PartitionSpec(*spec)


# --------------------------------------------------------------------------
# Block declarations
# --------------------------------------------------------------------------


def _attn_decl(cfg: ModelConfig, m) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": P((d, H * hd), ("d_model", "fused_heads")),
        "wk": P((d, KV * hd), ("d_model", "fused_heads")),
        "wv": P((d, KV * hd), ("d_model", "fused_heads")),
        "wo": P((H * hd, d), ("fused_heads", "d_model")),
    }


def _mamba_decl(cfg: ModelConfig, m) -> dict:
    d = cfg.d_model
    d_in = m.expand * d
    dt_rank = math.ceil(d / 16)
    return {
        "in_proj": P((d, 2 * d_in), ("d_model", "d_inner")),
        "conv_w": P((m.d_conv, d_in), (None, "d_inner")),
        "conv_b": P((d_in,), ("d_inner",), "zeros"),
        "x_proj": P((d_in, dt_rank + 2 * m.d_state), ("d_inner", None)),
        "dt_proj": P((dt_rank, d_in), (None, "d_inner")),
        "dt_bias": P((d_in,), ("d_inner",), "mamba_dt"),
        "A_log": P((d_in, m.d_state), ("d_inner", None), "mamba_A"),
        "D": P((d_in,), ("d_inner",), "ones"),
        "out_proj": P((d_in, d), ("d_inner", "d_model")),
    }


def _rwkv6_decl(cfg: ModelConfig, m) -> dict:
    d = cfg.d_model
    r = m.decay_lora
    return {
        # token-shift interpolation weights (data-independent part)
        "mix_r": P((d,), (None,), "normal:0.02"),
        "mix_k": P((d,), (None,), "normal:0.02"),
        "mix_v": P((d,), (None,), "normal:0.02"),
        "mix_g": P((d,), (None,), "normal:0.02"),
        "mix_w": P((d,), (None,), "normal:0.02"),
        "wr": P((d, d), ("d_model", "d_inner")),
        "wk": P((d, d), ("d_model", "d_inner")),
        "wv": P((d, d), ("d_model", "d_inner")),
        "wg": P((d, d), ("d_model", "d_inner")),
        "wo": P((d, d), ("d_inner", "d_model")),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": P((d,), (None,), "normal:0.02"),
        "wA": P((d, r), ("d_model", None)),
        "wB": P((r, d), (None, "d_inner")),
        "bonus": P((d // m.head_dim, m.head_dim), (None, None), "normal:0.02"),
        "ln_x": P((d,), (None,), "ones"),   # per-head group norm scale
    }


def _dense_decl(cfg: ModelConfig, f) -> dict:
    d = cfg.d_model
    if f.act == "rwkv_cmix":
        # RWKV-6 channel mix: token-shift lerp + squared-relu + receptance gate
        return {
            "mix_k": P((d,), (None,), "normal:0.02"),
            "mix_r": P((d,), (None,), "normal:0.02"),
            "wk": P((d, f.d_ff), ("d_model", "ffn")),
            "wv": P((f.d_ff, d), ("ffn", "ffn2")),
            "wr": P((d, d), ("d_model", "d_inner")),
        }
    if f.act == "swiglu":
        return {
            "wi0": P((d, f.d_ff), ("d_model", "ffn")),
            "wi1": P((d, f.d_ff), ("d_model", "ffn")),
            "wo": P((f.d_ff, d), ("ffn", "ffn2")),
        }
    return {
        "wi": P((d, f.d_ff), ("d_model", "ffn")),
        "wo": P((f.d_ff, d), ("ffn", "ffn2")),
    }


def _moe_decl(cfg: ModelConfig, f) -> dict:
    d, E = cfg.d_model, f.num_experts
    decl = {"router": P((d, E), ("d_model", None), "normal:0.02")}
    if f.act == "swiglu":
        decl.update({
            "wi0": P((E, d, f.d_ff), ("experts", "d_model", "ffn")),
            "wi1": P((E, d, f.d_ff), ("experts", "d_model", "ffn")),
            "wo": P((E, f.d_ff, d), ("experts", "ffn", "d_model")),
        })
    else:
        decl.update({
            "wi": P((E, d, f.d_ff), ("experts", "d_model", "ffn")),
            "wo": P((E, f.d_ff, d), ("experts", "ffn", "d_model")),
        })
    return decl


_MIXER_DECL = {"attn": _attn_decl, "mamba": _mamba_decl, "rwkv6": _rwkv6_decl}
_FFN_DECL = {"dense": _dense_decl, "moe": _moe_decl}


def _layer_decl(cfg: ModelConfig, layer) -> dict:
    return {
        "norm1": P((cfg.d_model,), (None,), "ones"),
        "mixer": _MIXER_DECL[layer.mixer.kind](cfg, layer.mixer),
        "norm2": P((cfg.d_model,), (None,), "ones"),
        "ffn": _FFN_DECL[layer.ffn.kind](cfg, layer.ffn),
    }


def _stack(decl: dict, n: int):
    """Prepend a `stack` dim of size n to every leaf (scanned period weights)."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, ("stack",) + p.axes, p.init), decl,
        is_leaf=lambda x: isinstance(x, P))


def declare_model(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    decl = {
        "embed": P((V, d), ("vocab", "d_model"), "normal:0.02"),
        "final_norm": P((d,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        decl["lm_head"] = P((d, V), ("d_model", "vocab"))
    if cfg.frontend:
        decl["frontend_proj"] = P((cfg.frontend_dim, d), ("frontend", "d_model"))
    if cfg.head:
        decl["head"] = {f"layer{i}": _layer_decl(cfg, l) for i, l in enumerate(cfg.head)}
    if cfg.num_periods:
        period = {f"block{i}": _layer_decl(cfg, l) for i, l in enumerate(cfg.period)}
        decl["period"] = _stack(period, cfg.num_periods)
    if cfg.tail:
        decl["tail"] = {f"layer{i}": _layer_decl(cfg, l) for i, l in enumerate(cfg.tail)}
    if cfg.early_exit_periods:
        decl["exit_heads"] = {
            f"exit{i}": {"norm": P((d,), (None,), "ones"),
                         "proj": P((d, V), ("d_model", "vocab"))}
            for i in cfg.early_exit_periods}
    return decl


# --------------------------------------------------------------------------
# Derivations
# --------------------------------------------------------------------------

_IS_P = lambda x: isinstance(x, P)  # noqa: E731


def _init_leaf(p: P, key, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "mamba_A":
        # S4D-real init: A = -(1..d_state), stored as log
        n = p.shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), p.shape)
        return jnp.log(a).astype(dtype)
    if p.init == "mamba_dt":
        # dt bias such that softplus(bias) ~ U[1e-3, 1e-1]
        u = jax.random.uniform(key, p.shape, jnp.float32, 1e-3, 1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(dtype)  # inv softplus
    if p.init.startswith("normal:"):
        s = float(p.init.split(":")[1])
        return (jax.random.normal(key, p.shape, jnp.float32) * s).astype(dtype)
    # fan_in
    fan_in = p.shape[0] if len(p.shape) == 1 else math.prod(p.shape[:-1])
    if "stack" in p.axes:
        fan_in //= p.shape[0]
    s = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * s).astype(dtype)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    decl = declare_model(cfg)
    leaves, treedef = jax.tree.flatten(decl, is_leaf=_IS_P)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_leaf(p, k, dtype)
                                        for p, k in zip(leaves, keys)])


def param_shapes(cfg: ModelConfig, dtype=jnp.bfloat16, mesh=None):
    """ShapeDtypeStructs (with shardings when mesh given) for dry-run lowering."""
    decl = declare_model(cfg)

    def leaf(p: P):
        if mesh is not None:
            s = jax.sharding.NamedSharding(mesh, logical_to_spec(p, mesh, cfg.fsdp))
            return jax.ShapeDtypeStruct(p.shape, dtype, sharding=s)
        return jax.ShapeDtypeStruct(p.shape, dtype)

    return jax.tree.map(leaf, decl, is_leaf=_IS_P)


def param_specs(cfg: ModelConfig, mesh):
    decl = declare_model(cfg)
    return jax.tree.map(lambda p: logical_to_spec(p, mesh, cfg.fsdp), decl,
                        is_leaf=_IS_P)


def param_count_from_decl(cfg: ModelConfig) -> int:
    decl = declare_model(cfg)
    return sum(math.prod(p.shape)
               for p in jax.tree.leaves(decl, is_leaf=_IS_P))
