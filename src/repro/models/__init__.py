from repro.models import blocks, cache, params, transformer  # noqa: F401
from repro.models.params import init_params, param_shapes, param_specs
from repro.models.cache import cache_shapes, cache_specs, init_cache
from repro.models.transformer import decode_step, forward, prefill, train_logits

__all__ = [
    "blocks", "cache", "params", "transformer",
    "init_params", "param_shapes", "param_specs",
    "cache_shapes", "cache_specs", "init_cache",
    "decode_step", "forward", "prefill", "train_logits",
]
