"""Decode/prefill cache: per-layer state pytrees.

Cache structure mirrors the model layout::

    {"head": {"layer0": {...}}, "period": {"block0": stacked...}, "tail": ...}

Each layer slot is ``{"mixer": <per-kind state>, "ffn": <per-kind state>}``:

  * attn  -> {"k": [B,S,KV,hd], "v": [B,S,KV,hd]}
  * mamba -> {"conv": [B,d_conv-1,d_in], "ssm": [B,d_in,d_state] f32}
  * rwkv6 -> {"x_prev": [B,1,D], "state": [B,H,hd,hd] f32}
  * rwkv_cmix ffn -> {"x_prev": [B,1,D]}; other ffns -> {}

Period entries carry a leading ``num_periods`` stack dim (scanned).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import Layer, ModelConfig


class CP(NamedTuple):
    """Cache leaf declaration: shape + logical axes + dtype."""
    shape: tuple
    axes: tuple
    dtype: object


def _mixer_cache_decl(cfg: ModelConfig, m, B: int, S: int, dtype) -> dict:
    if m.kind == "attn":
        kv = (B, S, cfg.num_kv_heads, cfg.head_dim)
        ax = ("batch", "kv_seq", "kv_heads", None)
        if cfg.kv_quant == "int8":
            sc = (B, S, cfg.num_kv_heads)
            sax = ("batch", "kv_seq", "kv_heads")
            return {"k": CP(kv, ax, jnp.int8), "v": CP(kv, ax, jnp.int8),
                    "k_scale": CP(sc, sax, jnp.float32),
                    "v_scale": CP(sc, sax, jnp.float32)}
        return {"k": CP(kv, ax, dtype), "v": CP(kv, ax, dtype)}
    if m.kind == "mamba":
        d_in = m.expand * cfg.d_model
        return {"conv": CP((B, m.d_conv - 1, d_in), ("batch", None, "d_inner"), dtype),
                "ssm": CP((B, d_in, m.d_state), ("batch", "d_inner", None), jnp.float32)}
    if m.kind == "rwkv6":
        h = cfg.d_model // m.head_dim
        return {"x_prev": CP((B, 1, cfg.d_model), ("batch", None, None), dtype),
                "state": CP((B, h, m.head_dim, m.head_dim),
                            ("batch", "heads", None, None), jnp.float32)}
    raise ValueError(m.kind)


def _ffn_cache_decl(cfg: ModelConfig, f, B: int, dtype) -> dict:
    if f.kind == "dense" and f.act == "rwkv_cmix":
        return {"x_prev": CP((B, 1, cfg.d_model), ("batch", None, None), dtype)}
    return {}


def _layer_cache_decl(cfg, layer: Layer, B, S, dtype):
    return {"mixer": _mixer_cache_decl(cfg, layer.mixer, B, S, dtype),
            "ffn": _ffn_cache_decl(cfg, layer.ffn, B, dtype)}


def _stack(decl, n):
    return jax.tree.map(
        lambda c: CP((n,) + c.shape, ("stack",) + c.axes, c.dtype), decl,
        is_leaf=lambda x: isinstance(x, CP))


def declare_cache(cfg: ModelConfig, batch: int, seq_len: int,
                  dtype=jnp.bfloat16) -> dict:
    decl = {}
    if cfg.head:
        decl["head"] = {f"layer{i}": _layer_cache_decl(cfg, l, batch, seq_len, dtype)
                        for i, l in enumerate(cfg.head)}
    if cfg.num_periods:
        period = {f"block{i}": _layer_cache_decl(cfg, l, batch, seq_len, dtype)
                  for i, l in enumerate(cfg.period)}
        decl["period"] = _stack(period, cfg.num_periods)
    if cfg.tail:
        decl["tail"] = {f"layer{i}": _layer_cache_decl(cfg, l, batch, seq_len, dtype)
                        for i, l in enumerate(cfg.tail)}
    return decl


_IS_CP = lambda x: isinstance(x, CP)  # noqa: E731


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.float32):
    decl = declare_cache(cfg, batch, seq_len, dtype)
    return jax.tree.map(lambda c: jnp.zeros(c.shape, c.dtype), decl,
                        is_leaf=_IS_CP)


# --------------------------------------------------------------------------
# Block-paged variant (serving)
# --------------------------------------------------------------------------


def _page_leaf(c: CP, num_blocks: int, block_size: int) -> CP:
    """Rewrite an attention KV leaf ``[.., batch, kv_seq(=block_size), ..]``
    into the shared block-pool layout ``[.., kv_blocks, block, ..]``.
    Leaves without a ``kv_seq`` axis (recurrent state) keep their per-row
    layout untouched."""
    if "kv_seq" not in c.axes:
        return c
    shape, axes = list(c.shape), list(c.axes)
    b, s = axes.index("batch"), axes.index("kv_seq")
    shape[b], axes[b] = num_blocks, "kv_blocks"
    shape[s], axes[s] = block_size, "block"
    return CP(tuple(shape), tuple(axes), c.dtype)


def declare_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                        block_size: int, dtype=jnp.bfloat16) -> dict:
    """Cache declaration with attention KV in a shared block pool.

    Attention k/v (and int8 scales) become ``[num_blocks, block_size,
    kv_heads, hd]`` — one pool per layer, rows indexed through per-request
    page tables (see ``repro.serving.slots`` / ``kernels.paged_attention``).
    Recurrent state (mamba conv/ssm, rwkv6, rwkv_cmix x_prev) has no seq
    dim and stays ``[batch, ...]`` per request row.  Block 0 is reserved as
    the null block page tables are padded with.
    """
    decl = declare_cache(cfg, batch, block_size, dtype)
    return jax.tree.map(lambda c: _page_leaf(c, num_blocks, block_size),
                        decl, is_leaf=_IS_CP)


def init_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_size: int, dtype=jnp.float32):
    decl = declare_paged_cache(cfg, batch, num_blocks, block_size, dtype)
    return jax.tree.map(lambda c: jnp.zeros(c.shape, c.dtype), decl,
                        is_leaf=_IS_CP)


def has_recurrent_state(cfg: ModelConfig) -> bool:
    """True if any cache leaf is per-request recurrent state (no kv_seq
    dim): such state advances on every fused decode step, so rows cannot
    be replayed after a block-exhaustion stall (see CascadeEngine)."""
    decl = declare_cache(cfg, 1, 1)
    flags = []
    jax.tree.map(lambda c: flags.append("kv_seq" not in c.axes), decl,
                 is_leaf=_IS_CP)
    return any(flags)


def cache_spec_leaf(c: CP, mesh, *, shard_seq: bool,
                    seq_over_model: bool = False) -> PartitionSpec:
    """Sharding rule for one cache leaf.

    Default: batch -> ('pod','data'), kv heads/d_inner -> 'model' when
    divisible.  Block-paged leaves (``declare_paged_cache``) shard their
    ``kv_blocks`` pool dim over ('pod','data') the same way — each data
    shard owns a contiguous range of KV blocks, matching the serving
    engine's shard-aware ``BlockAllocator`` so a request's blocks live on
    the shard that decodes its row; the intra-block ``block`` dim is
    never sharded.  When ``shard_seq`` (long-context, batch=1): the KV
    seq dim is sharded over 'data' (sequence-parallel cache) instead of
    batch.
    ``seq_over_model``: additionally shard the KV seq dim over 'model' —
    the §Perf lever for GQA archs whose kv_heads don't divide the model
    axis (their caches otherwise replicate across it; attention reductions
    over the sharded seq dim become all-reduces).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = tuple(a for a in ("pod", "data") if a in sizes)
    data_total = math.prod(sizes[a] for a in data_axes) if data_axes else 1
    model = sizes.get("model", 1)
    kv_shardable = any(a in ("kv_heads", "d_inner", "heads")
                       and s % model == 0
                       for a, s in zip(c.axes, c.shape)) and model > 1
    spec = [None] * len(c.shape)
    for i, (a, s) in enumerate(zip(c.axes, c.shape)):
        if a in ("batch", "kv_blocks") and not shard_seq \
                and data_total > 1 and s % data_total == 0:
            spec[i] = data_axes if len(data_axes) > 1 else data_axes[0]
        elif a == "kv_seq":
            axes = []
            if shard_seq and data_total > 1:
                axes.extend(data_axes)
            if seq_over_model and not kv_shardable and model > 1:
                axes.append("model")
            total = math.prod(sizes[x] for x in axes) if axes else 1
            if axes and s % total == 0:
                spec[i] = tuple(axes) if len(axes) > 1 else axes[0]
        elif a in ("kv_heads", "d_inner", "heads") and model > 1 and s % model == 0:
            spec[i] = "model"
    return PartitionSpec(*spec)


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int, mesh=None,
                 dtype=jnp.bfloat16, shard_seq: bool = False,
                 seq_over_model: bool = False):
    """ShapeDtypeStructs (with shardings when mesh given) for dry-run."""
    decl = declare_cache(cfg, batch, seq_len, dtype)

    def leaf(c: CP):
        if mesh is not None:
            s = jax.sharding.NamedSharding(
                mesh, cache_spec_leaf(c, mesh, shard_seq=shard_seq,
                                      seq_over_model=seq_over_model))
            return jax.ShapeDtypeStruct(c.shape, c.dtype, sharding=s)
        return jax.ShapeDtypeStruct(c.shape, c.dtype)

    return jax.tree.map(leaf, decl, is_leaf=_IS_CP)


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int, mesh,
                dtype=jnp.bfloat16, shard_seq: bool = False,
                seq_over_model: bool = False):
    decl = declare_cache(cfg, batch, seq_len, dtype)
    return jax.tree.map(
        lambda c: cache_spec_leaf(c, mesh, shard_seq=shard_seq,
                                  seq_over_model=seq_over_model),
        decl, is_leaf=_IS_CP)


def paged_cache_specs(cfg: ModelConfig, batch: int, num_blocks: int,
                      block_size: int, mesh, dtype=jnp.bfloat16):
    """PartitionSpecs for a block-paged serving cache on ``mesh``: the
    ``kv_blocks`` pool dim and per-row recurrent ``batch`` dims shard
    over ('pod','data'), kv heads over 'model' when divisible."""
    decl = declare_paged_cache(cfg, batch, num_blocks, block_size, dtype)
    return jax.tree.map(lambda c: cache_spec_leaf(c, mesh, shard_seq=False),
                        decl, is_leaf=_IS_CP)
