"""Classifier zoo for the paper-faithful reproduction (Tables 1–6, Fig 3–5).

The container has no CIFAR-100/ImageNet, so the zoo re-creates the paper's
*relative structure* on the synthetic hierarchical-mixture task: a family
of MLP classifiers whose analytic MACs and capacities mirror the ordering
of (MobileNetV2, VGG11, AlexNet, ResNet18, ResNet152) in Table 1 — a
shallow-but-wide member with poor cost/accuracy (AlexNet's role), compact
members, and deep expensive members that are genuinely more accurate.

Also includes the early-exit stack (the MSDNet stand-in for Fig 3): one
backbone with exit heads after chosen depths, trained jointly (Eq 6).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.optim import get_optimizer


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


@dataclass(frozen=True)
class MLPConfig:
    name: str
    width: int                        # residual trunk width
    depth: int                        # number of residual blocks
    num_classes: int
    in_dim: int

    @property
    def macs(self) -> int:
        # stem + depth residual blocks (2 matmuls each) + head
        return (self.in_dim * self.width
                + self.depth * 2 * self.width * self.width
                + self.width * self.num_classes)


def zoo(in_dim: int, num_classes: int) -> dict:
    """The five paper roles.  MACs ordering mirrors Table 1
    (mobilenet < vgg < alexnet < resnet18 << resnet152) and the AlexNet
    member is wide-but-shallow: costly without matching accuracy."""
    return {
        "mobilenetv2": MLPConfig("mobilenetv2", 64, 2, num_classes, in_dim),
        "vgg11": MLPConfig("vgg11", 96, 3, num_classes, in_dim),
        "alexnet": MLPConfig("alexnet", 160, 1, num_classes, in_dim),
        "resnet18": MLPConfig("resnet18", 128, 6, num_classes, in_dim),
        "resnet152": MLPConfig("resnet152", 224, 12, num_classes, in_dim),
    }


def init_mlp(cfg: MLPConfig, key):
    key, k = jax.random.split(key)
    params = {"stem": {"w": jax.random.normal(k, (cfg.in_dim, cfg.width))
                       * math.sqrt(2.0 / cfg.in_dim),
                       "b": jnp.zeros((cfg.width,))},
              "blocks": [], }
    for _ in range(cfg.depth):
        key, k1, k2 = jax.random.split(key, 3)
        params["blocks"].append({
            "w1": jax.random.normal(k1, (cfg.width, cfg.width))
            * math.sqrt(2.0 / cfg.width),
            "b1": jnp.zeros((cfg.width,)),
            "w2": jax.random.normal(k2, (cfg.width, cfg.width))
            * math.sqrt(0.5 / cfg.width),   # small init: near-identity blocks
            "b2": jnp.zeros((cfg.width,)),
        })
    key, k = jax.random.split(key)
    params["head"] = {"w": jax.random.normal(k, (cfg.width, cfg.num_classes))
                      / math.sqrt(cfg.width),
                      "b": jnp.zeros((cfg.num_classes,))}
    return params


def _lnorm(h):
    m = jnp.mean(h, -1, keepdims=True)
    v = jnp.var(h, -1, keepdims=True)
    return (h - m) * jax.lax.rsqrt(v + 1e-6)


def mlp_apply(params, x, *, with_features: bool = False):
    h = jax.nn.relu(x @ params["stem"]["w"] + params["stem"]["b"])
    for blk in params["blocks"]:
        u = jax.nn.relu(_lnorm(h) @ blk["w1"] + blk["b1"])   # pre-norm residual
        h = h + (u @ blk["w2"] + blk["b2"])
    feats = h
    logits = h @ params["head"]["w"] + params["head"]["b"]
    if with_features:
        return logits, feats
    return logits


# --------------------------------------------------------------------------
# Training (original loss or LtC — Eq 4)
# --------------------------------------------------------------------------


def train_classifier(cfg: MLPConfig, data_x, data_y, *, key,
                     exp_logits=None, ltc_w: float = 0.0, cost_c: float = 0.5,
                     epochs: int = 30, batch_size: int = 256, lr: float = 0.05,
                     weight_decay: float = 5e-4, conf_head: bool = False,
                     conf_head_kind: str = "confnet", verbose: bool = False):
    """SGD+momentum training (the paper's optimizer, step-decayed LR).

    exp_logits + ltc_w > 0 => LtC training (Eq 4) with the frozen expensive
    model's precomputed logits.  conf_head => jointly train an auxiliary
    confidence head (ConfNet / IDK baselines).
    """
    params = init_mlp(cfg, key)
    if conf_head:
        kh, key = jax.random.split(key)
        hid = cfg.width
        head = {"w1": jax.random.normal(kh, (hid, 64)) / math.sqrt(hid),
                "b1": jnp.zeros((64,)),
                "w2": jnp.zeros((64, 1)), "b2": jnp.zeros((1,))}
        params = {"mlp": params, "head": head}

    opt = get_optimizer("sgd_momentum", momentum=0.9, weight_decay=weight_decay)
    state = opt.init(params)
    n = data_x.shape[0]
    steps_per_epoch = max(1, n // batch_size)
    total = epochs * steps_per_epoch
    b1, b2 = int(0.3 * total), int(0.6 * total)

    def loss_fn(p, xb, yb, eb):
        mlp = p["mlp"] if conf_head else p
        logits, feats = mlp_apply(mlp, xb, with_features=True)
        l = losses.cross_entropy(logits, yb)
        metrics = {}
        if ltc_w > 0.0 and eb is not None:
            l_casc = losses.cascade_loss(logits, eb, yb, cost_c)
            l = l + ltc_w * l_casc
            metrics["l_casc"] = l_casc
        if conf_head:
            h = jax.nn.relu(feats @ p["head"]["w1"] + p["head"]["b1"])
            conf = jax.nn.sigmoid((h @ p["head"]["w2"] + p["head"]["b2"])[..., 0])
            if conf_head_kind == "confnet":
                l = l + losses.confnet_loss(conf, logits, yb)
            else:
                l = l + losses.idk_loss(conf, logits, yb, cost_c)
        return l, metrics

    @jax.jit
    def step(p, s, xb, yb, eb, lr_now):
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, xb, yb, eb)
        g = clip_by_global_norm(g, 1.0)
        p, s = opt.update(p, g, s, lr_now)
        return p, s, l

    rng = jax.random.PRNGKey(hash(cfg.name) % (2 ** 31))
    t = 0
    for ep in range(epochs):
        rng, kp = jax.random.split(rng)
        perm = jax.random.permutation(kp, n)
        for i in range(steps_per_epoch):
            sl = perm[i * batch_size:(i + 1) * batch_size]
            xb, yb = data_x[sl], data_y[sl]
            eb = exp_logits[sl] if exp_logits is not None else None
            lr_now = lr * (0.2 ** ((t >= b1) + (t >= b2)))
            params, state, l = step(params, state, xb, yb, eb, lr_now)
            t += 1
        if verbose and (ep + 1) % 10 == 0:
            print(f"  [{cfg.name}] epoch {ep+1}: loss {float(l):.4f}")
    return params


def predict(params, x, *, conf_head: bool = False):
    """Returns (logits, conf_head_scores or None)."""
    if conf_head:
        logits, feats = mlp_apply(params["mlp"], x, with_features=True)
        h = jax.nn.relu(feats @ params["head"]["w1"] + params["head"]["b1"])
        conf = jax.nn.sigmoid((h @ params["head"]["w2"] + params["head"]["b2"])[..., 0])
        return logits, conf
    return mlp_apply(params, x), None


# --------------------------------------------------------------------------
# Early-exit backbone (MSDNet stand-in, Fig 3) — Eq 6 joint training
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class EarlyExitConfig:
    name: str
    widths: Tuple[int, ...]          # backbone widths, one block per entry
    exits: Tuple[int, ...]           # exit after block i (0-based); last
                                     # block always has the final exit
    num_classes: int
    in_dim: int

    def macs_upto(self, exit_idx: int) -> int:
        """Cumulative MACs through exit `exit_idx` (incl. its head)."""
        dims = (self.in_dim,) + self.widths
        block_end = (self.exits + (len(self.widths) - 1,))[exit_idx]
        macs = sum(dims[i] * dims[i + 1] for i in range(block_end + 1))
        macs += dims[block_end + 1] * self.num_classes
        return macs


def init_early_exit(cfg: EarlyExitConfig, key):
    dims = (cfg.in_dim,) + cfg.widths
    blocks, heads = [], []
    for a, b in zip(dims[:-1], dims[1:]):
        key, k = jax.random.split(key)
        blocks.append({"w": jax.random.normal(k, (a, b)) * math.sqrt(2.0 / a),
                       "b": jnp.zeros((b,))})
    for i in tuple(cfg.exits) + (len(cfg.widths) - 1,):
        key, k = jax.random.split(key)
        d = cfg.widths[i]
        heads.append({"w": jax.random.normal(k, (d, cfg.num_classes)) / math.sqrt(d),
                      "b": jnp.zeros((cfg.num_classes,))})
    return {"blocks": blocks, "heads": heads}


def early_exit_apply(params, cfg: EarlyExitConfig, x):
    """Returns list of logits, one per exit (fast -> final)."""
    outs = []
    h = x
    exit_points = tuple(cfg.exits) + (len(cfg.widths) - 1,)
    head_i = 0
    for i, blk in enumerate(params["blocks"]):
        h = jax.nn.relu(h @ blk["w"] + blk["b"])
        if head_i < len(exit_points) and i == exit_points[head_i]:
            hd = params["heads"][head_i]
            outs.append(h @ hd["w"] + hd["b"])
            head_i += 1
    return outs


def train_early_exit(cfg: EarlyExitConfig, data_x, data_y, *, key,
                     ltc_w: float = 0.0, cost_c: float = 0.5,
                     epochs: int = 30, batch_size: int = 256, lr: float = 0.05):
    """Joint training of all exits; ltc_w>0 adds Eq 6's pairwise L_casc."""
    params = init_early_exit(cfg, key)
    opt = get_optimizer("sgd_momentum", momentum=0.9, weight_decay=5e-4)
    state = opt.init(params)
    n = data_x.shape[0]
    spe = max(1, n // batch_size)
    total = epochs * spe
    b1, b2 = int(0.3 * total), int(0.6 * total)

    def loss_fn(p, xb, yb):
        chain = early_exit_apply(p, cfg, xb)
        if ltc_w > 0:
            l, _ = losses.ltc_chain_loss(chain, yb, w=ltc_w, cost_c=cost_c)
        else:
            l = sum(losses.cross_entropy(c, yb) for c in chain)
        return l

    @jax.jit
    def step(p, s, xb, yb, lr_now):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        g = clip_by_global_norm(g, 1.0)
        p, s = opt.update(p, g, s, lr_now)
        return p, s, l

    rng = jax.random.PRNGKey(0)
    t = 0
    for ep in range(epochs):
        rng, kp = jax.random.split(rng)
        perm = jax.random.permutation(kp, n)
        for i in range(spe):
            sl = perm[i * batch_size:(i + 1) * batch_size]
            lr_now = lr * (0.2 ** ((t >= b1) + (t >= b2)))
            params, state, _ = step(params, state, data_x[sl], data_y[sl], lr_now)
            t += 1
    return params
