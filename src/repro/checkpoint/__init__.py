from repro.checkpoint.checkpoint import load, save

__all__ = ["load", "save"]
