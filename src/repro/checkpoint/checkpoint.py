"""Checkpointing: pytree <-> .npz with structure-preserving keys.

Arrays are gathered to host, saved flat (path-joined keys), and restored
with optional resharding onto a mesh.  Deliberately dependency-free
(no orbax/tensorstore in this container); layout is stable and
human-inspectable with ``np.load``.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}{_SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def save(path: str, tree, step: Optional[int] = None) -> None:
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    meta = {"keys": sorted(arrays), "step": step}
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def load(path: str, like=None, mesh=None, specs=None):
    """Restore a checkpoint.  If ``like`` (a pytree of arrays or
    ShapeDtypeStructs) is given, the result has that exact structure; with
    ``mesh``+``specs`` arrays are placed sharded."""
    if not path.endswith(".npz"):
        path += ".npz"
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files if k != "__meta__"}
    if like is None:
        # rebuild a nested dict
        tree: dict = {}
        for k, v in arrays.items():
            parts = k.split(_SEP)
            node = tree
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = v
        return tree
    flat_like = _flatten(like)
    missing = set(flat_like) - set(arrays)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")

    def place(k, proto):
        a = arrays[k].astype(proto.dtype)
        if mesh is not None and specs is not None:
            spec = _flatten(specs)[k]
            return jax.device_put(a, jax.sharding.NamedSharding(mesh, spec))
        return jax.numpy.asarray(a)

    leaves, treedef = jax.tree.flatten(like)
    keys = sorted(flat_like)
    # rebuild in like's flatten order
    restored_flat = {k: place(k, v) for k, v in flat_like.items()}
    out_leaves = [restored_flat[k] for k in _flatten_keys_in_order(like)]
    return jax.tree.unflatten(treedef, out_leaves)


def _flatten_keys_in_order(tree, prefix=""):
    keys = []
    if isinstance(tree, dict):
        # jax.tree flattens dicts in sorted-key order; mirror it
        for k in sorted(tree):
            keys.extend(_flatten_keys_in_order(tree[k], f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            keys.extend(_flatten_keys_in_order(v, f"{prefix}#{i}{_SEP}"))
    else:
        keys.append(prefix[:-1])
    return keys
