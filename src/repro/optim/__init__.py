from repro.optim.optimizer import (Optimizer, adafactor, adamw, cosine,
                                   get_optimizer, sgd_momentum, step_decay)

__all__ = ["Optimizer", "adafactor", "adamw", "cosine", "get_optimizer",
           "sgd_momentum", "step_decay"]
