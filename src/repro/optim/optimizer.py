"""Optimizers (pytree-functional, no external deps).

API::

    opt = sgd_momentum(momentum=0.9, weight_decay=5e-4)
    state = opt.init(params)
    params, state = opt.update(params, grads, state, lr)

Provided: SGD+momentum (the paper's optimizer), AdamW, and Adafactor
(factored second moment, no momentum — the memory-lean choice the launcher
uses for the trillion-parameter dry-run).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (params, grads, state, lr) -> (params, state)
    name: str


def sgd_momentum(momentum: float = 0.9, weight_decay: float = 0.0,
                 nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        if nesterov:
            step_dir = jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
        else:
            step_dir = mu
        params = jax.tree.map(lambda p, d: (p - lr * d).astype(p.dtype),
                              params, step_dir)
        return params, {"mu": mu, "step": state["step"] + 1}

    return Optimizer(init, update, "sgd_momentum")


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(jnp.zeros_like, params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        t = state["step"] + 1
        m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, state["v"], grads)
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, mm, vv):
            step_dir = (mm / c1) / (jnp.sqrt(vv / c2) + eps)
            return (p - lr * (step_dir + weight_decay * p)).astype(p.dtype)

        return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "step": t}

    return Optimizer(init, update, "adamw")


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern 2018), no momentum.

    For >=2D leaves it stores row/col statistics only (O(n+m) per (n,m)
    matrix) — the optimizer of choice when parameters alone nearly fill
    HBM (kimi-k2 dry-run)."""

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(leaf, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        t = state["step"] + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** -decay

        def upd(p, g, s):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                precond = (vr[..., None] / jnp.maximum(denom[..., None], eps)) \
                    * vc[..., None, :]
                u = g32 * jax.lax.rsqrt(jnp.maximum(precond, eps))
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(jnp.maximum(v, eps))
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, {"v": new_s, "step": t}

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd_momentum, "sgd_momentum": sgd_momentum,
            "adamw": adamw, "adafactor": adafactor}[name](**kw)


# ---- learning-rate schedules ----------------------------------------------


def step_decay(base: float, boundaries, factor: float):
    """The paper's schedule: lr *= factor at each boundary (epochs/steps)."""
    bs = jnp.asarray(boundaries)

    def lr(step):
        n = jnp.sum(step >= bs)
        return base * factor ** n

    return lr


def cosine(base: float, total_steps: int, warmup: int = 0,
           min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = base * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return lr
