"""Production meshes.

Target hardware: TPU v5e pods — 256 chips per pod, 2 pods for the
multi-pod configuration.  Axes:

  * ``data``  — batch (and, for batch=1 long-context, KV-cache sequence)
  * ``model`` — tensor/expert parallelism
  * ``pod``   — data parallelism across pods (multi-pod only)

Defined as functions (never module-level constants) so importing this
module touches no jax device state.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def _mk(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:           # jax >= 0.5
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)   # 0.4.x: Auto is the only mode


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(devices: int = 8):
    """Small mesh for CI-style dry-run tests (host platform devices)."""
    if devices % 4 == 0:
        return _mk((devices // 4, 4), ("data", "model"))
    return _mk((1, devices), ("data", "model"))


def make_tier_mesh(data: int = 1, model: int = 1, devices=None):
    """A ('data','model') mesh for one cascade tier.

    Multi-tier serving gives each tier its own mesh over a *subset* of
    the host's devices (the heavy tier typically gets more chips), so
    unlike :func:`make_test_mesh` this accepts an explicit device list.
    With ``devices=None`` and ``data*model`` covering every local device
    it defers to the :func:`_mk` compat helper (AxisType on jax >= 0.5);
    otherwise it builds the Mesh over the given slice directly.
    """
    import numpy as np
    shape, axes = (data, model), ("data", "model")
    if devices is None:
        devices = jax.devices()
        if data * model == len(devices):
            return _mk(shape, axes)
        devices = devices[:data * model]
    if len(devices) != data * model:
        raise ValueError(f"tier mesh {data}x{model} needs {data * model} "
                         f"devices, got {len(devices)}")
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_tier_meshes(shapes, devices=None):
    """One mesh per cascade tier from ``[(data, model), ...]`` shapes.

    Devices are assigned contiguously from ``jax.devices()`` so tiers
    occupy disjoint chip sets when they fit side by side (tier 0 on the
    first ``d0*m0`` chips, tier 1 on the next ``d1*m1``, ...); when a
    tier would run past the end, assignment wraps to device 0 and tiers
    share chips (JAX multiplexes fine on a single host).
    """
    devs = list(jax.devices()) if devices is None else list(devices)
    meshes, off = [], 0
    for data, model in shapes:
        n = data * model
        if n > len(devs):
            raise ValueError(f"tier mesh {data}x{model} needs {n} devices, "
                             f"only {len(devs)} available")
        if off + n > len(devs):
            off = 0                       # wrap: tiers share devices
        meshes.append(make_tier_mesh(data, model, devs[off:off + n]))
        off += n
    return meshes


def num_chips(mesh) -> int:
    return mesh.devices.size
