"""Production meshes.

Target hardware: TPU v5e pods — 256 chips per pod, 2 pods for the
multi-pod configuration.  Axes:

  * ``data``  — batch (and, for batch=1 long-context, KV-cache sequence)
  * ``model`` — tensor/expert parallelism
  * ``pod``   — data parallelism across pods (multi-pod only)

Defined as functions (never module-level constants) so importing this
module touches no jax device state.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def _mk(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:           # jax >= 0.5
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)   # 0.4.x: Auto is the only mode


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(devices: int = 8):
    """Small mesh for CI-style dry-run tests (host platform devices)."""
    if devices % 4 == 0:
        return _mk((devices // 4, 4), ("data", "model"))
    return _mk((1, devices), ("data", "model"))


def num_chips(mesh) -> int:
    return mesh.devices.size
