"""Distributed launch layer: production meshes, input shape specs, step
functions, the multi-pod dry-run, and the roofline extraction that reads
its compiled artifacts.  ``repro.launch.dryrun`` must stay import-safe
only as __main__ (it sets XLA_FLAGS at import)."""
from repro.launch import hlo, mesh, roofline, shapes, steps  # noqa: F401
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.shapes import SHAPES, input_specs

__all__ = ["hlo", "mesh", "roofline", "shapes", "steps",
           "make_production_mesh", "make_test_mesh", "SHAPES", "input_specs"]
