"""Asynchronous cascade serving under Poisson traffic.

Drives :class:`repro.serving.CascadeEngine` with open-loop arrivals:
requests arrive at rate ``--rate`` req/s (exponential inter-arrival
times), are admitted into ``--slots`` KV slots per tier as they free up
(continuous batching), and low-confidence sequences are escalated to the
expensive tier through packed escalation queues.

The gate threshold is set from an escalation *budget* by default
(δ = the budget-quantile of recently observed sequence confidences —
the operator caps cost, the runtime finds δ); pass ``--delta`` for a
fixed threshold instead.

    PYTHONPATH=src python -m repro.launch.serve_async \
        --requests 64 --rate 8 --slots 8

Reports p50/p95 latency, time-to-first-token, throughput, per-tier
utilization, escalation rate, and Eq 7 FLOPs/request vs the
always-fast / always-expensive envelopes.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import bigram_lm
from repro.models import init_params
from repro.serving import CascadeEngine, TierSpec
from repro.serving.engine import VirtualClock, WallClock


def build_engine(args, clock=None):
    fast_cfg = get_config(args.fast, args.variant)
    exp_cfg = get_config(args.expensive, args.variant)
    fast_params = init_params(fast_cfg, jax.random.PRNGKey(args.seed),
                              jnp.float32)
    exp_params = init_params(exp_cfg, jax.random.PRNGKey(args.seed + 1),
                             jnp.float32)
    gate_kw = ({"deltas": [args.delta]} if args.delta is not None
               else {"escalation_budget": args.escalation_budget})
    engine = CascadeEngine(
        [TierSpec(args.fast, fast_cfg, fast_params),
         TierSpec(args.expensive, exp_cfg, exp_params)],
        slots=args.slots, prompt_len=args.prompt_len, gen_len=args.gen_len,
        use_gate_kernel=not args.no_gate_kernel,
        use_paged_kv=not args.dense_kv, kv_block_size=args.kv_block_size,
        kv_blocks=args.kv_blocks,
        clock=clock if clock is not None else WallClock(), **gate_kw)
    return engine, min(fast_cfg.vocab_size, exp_cfg.vocab_size)


def poisson_arrivals(n: int, rate: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def run(args, clock=None) -> dict:
    engine, vocab = build_engine(args, clock)
    prompts = bigram_lm(num_seqs=args.requests, seq_len=args.prompt_len,
                        vocab=vocab, seed=args.seed)
    arrivals = poisson_arrivals(args.requests, args.rate, args.seed)
    # warmup compiles every tier and then resets the clock, so arrival
    # timestamps are relative to the start of serving, not construction
    engine.warmup()
    for p, t in zip(prompts, arrivals):
        engine.submit(p, arrival_time=float(t))
    summary = engine.run()
    summary["rate"] = args.rate
    # realized offered load: completions can never beat this in an
    # open-loop run (makespan >= arrival span), a sanity bound on
    # the reported throughput
    summary["offered_rate"] = (
        args.requests / float(arrivals[-1] - arrivals[0])
        if args.requests > 1 and arrivals[-1] > arrivals[0]
        else float("nan"))
    summary["slots"] = args.slots
    summary["gen_len"] = args.gen_len
    summary["escalation_budget"] = (None if args.delta is not None
                                    else args.escalation_budget)
    summary["delta"] = [engine.scheduler.delta(g)
                        for g in range(len(engine.scheduler.gates))]
    # block-paged KV arena accounting (high-water = blocks actually
    # mapped at peak, the number the paged arena saves vs dense)
    summary["kv_arena"] = engine.memory_stats()
    return summary


def report(s: dict) -> None:
    unit = "s"
    print(f"served {s['completed']}/{s['requests']} requests "
          f"in {s['elapsed']:.2f}{unit} over {s['steps']} engine steps "
          f"(rate {s['rate']}/s, {s['slots']} slots/tier)")
    print(f"  latency  p50 {s['latency_p50']:.3f}{unit}  "
          f"p95 {s['latency_p95']:.3f}{unit}   "
          f"ttft p50 {s['ttft_p50']:.3f}{unit}  p95 {s['ttft_p95']:.3f}{unit}")
    print(f"  throughput {s['throughput']:.2f} req/{unit}   "
          f"tier utilization "
          + "  ".join(f"{n}={u:.2f}" for n, u in
                      zip(s['tier_names'], s['tier_utilization'])))
    rates = ", ".join(f"{r:.3f}" for r in s["escalation_rates"])
    deltas = ", ".join(f"{d:.4f}" for d in s["delta"])
    target = ("" if s.get("escalation_budget") is None
              else f" (budget target {s['escalation_budget']:.3f})")
    print(f"  escalation rate [{rates}] at δ=[{deltas}]{target}")
    print(f"  Eq7 FLOPs/request: cascade {s['flops_per_request_cascade']:.3e} "
          f"(always-fast {s['flops_per_request_always_fast']:.3e}, "
          f"always-expensive {s['flops_per_request_always_expensive']:.3e})")
    if s["flops_per_request_cascade"] \
            < s["flops_per_request_always_expensive"]:
        print("  cascade < always-expensive ✓")


def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fast", default="gemma3-1b")
    ap.add_argument("--expensive", default="phi4-mini-3.8b")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--slots", type=int, default=8,
                    help="KV slot pool size per tier")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--delta", type=float, default=None,
                    help="fixed gate threshold (overrides the budget)")
    ap.add_argument("--escalation-budget", type=float, default=0.25,
                    help="target escalation rate; δ is calibrated online")
    ap.add_argument("--no-gate-kernel", action="store_true",
                    help="jnp confidence instead of the Pallas gate kernel")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV block (paged arena)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="KV arena size in blocks per tier (default: fully "
                         "provisioned slots*pages_per_row+1; smaller "
                         "over-subscribes, attention-only models)")
    ap.add_argument("--dense-kv", action="store_true",
                    help="PR 1 dense one-page-per-request arena instead of "
                         "the block-paged arena + paged decode kernel")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="also write the summary dict to this path")
    ap.add_argument("--virtual-clock", action="store_true",
                    help="deterministic 1-tick-per-step clock (arrival "
                         "times are then in ticks, not seconds)")
    return ap


def main() -> None:
    args = make_parser().parse_args()
    clock = VirtualClock() if args.virtual_clock else None
    summary = run(args, clock)
    report(summary)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, default=float)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
