"""Asynchronous cascade serving under Poisson traffic.

Drives :class:`repro.serving.CascadeEngine` with open-loop arrivals:
requests arrive at rate ``--rate`` req/s (exponential inter-arrival
times), are admitted into ``--slots`` KV slots per tier as they free up
(continuous batching), and low-confidence sequences are escalated to the
expensive tier through packed escalation queues.

Real traffic has mixed prompt lengths: ``--length-dist
{uniform,lognormal,bimodal}`` samples a per-request length in
``[--min-prompt-len, --prompt-len]`` and the engine's chunked paged
prefill (``--prefill-chunk`` tokens per row per tick, admission capped at
``--prefill-token-budget`` tokens per tier per tick) serves them with no
cross-row padding beyond each row's last chunk.  Each tick runs as ONE
unified prefill+decode program per tier — by default the **ragged flat
token-batch** program, whose live tokens pack contiguously into a
``[1, W]`` batch at a bucketed power-of-two width (``--flat-buckets``
overrides the bucket set) so compute is O(live tokens);
``--no-ragged-step`` keeps the padded ``[slots, width]`` mixed program
and ``--split-step`` the legacy two-launch chunk+decode pair (the A/B
baselines; the summary reports realized launches/tick, the wasted-slot
ratio, and the compiled-program count either way).  ``--dense-kv`` or
``--no-chunked-prefill`` fall back to the uniform packed prefill
(uniform lengths only).

``--prefix-cache`` turns on refcounted KV prefix sharing (chunked paged
prefill only): each shard's pool indexes finished prompt chunks at block
boundaries, later requests with the same leading tokens map those blocks
read-only and start prefill at the first uncached chunk (cached tokens
cost 0 admission budget); writes past a shared prefix copy-on-write into
fresh blocks.  ``--shared-prefix-frac F`` makes the synthetic workload
exercise it: every request's first ``F``·length tokens come from one
shared base prompt (system-prompt traffic), the rest stay unique.  Token
streams are bit-identical with the cache on or off under a fixed
``--delta``; the summary records the hit rate, cached-token fraction,
and a stream checksum for cache-A/B comparison.

The gate threshold is set from an escalation *budget* by default
(δ = the budget-quantile of recently observed sequence confidences —
the operator caps cost, the runtime finds δ); pass ``--delta`` for a
fixed threshold instead.

Multi-device hosts can give each tier its own mesh: ``--tier-mesh 4x1
4x1`` runs the fast tier on the first four devices and the expensive
tier on the next four, request rows and the paged KV block pool sharded
over each mesh's data axis (``--shard-params`` additionally
tensor-shards params over 'model').  Token streams are bit-identical to
the single-device engine.

    PYTHONPATH=src python -m repro.launch.serve_async \
        --requests 64 --rate 8 --slots 8 --length-dist lognormal

Reports p50/p95 latency, time-to-first-token (overall and per
prompt-length bucket), throughput, per-tier utilization, escalation
rate, per-gate streaming calibration (ECE + cheap-vs-expensive
agreement over escalation outcomes), live-vs-processed prefill token
ratio, and Eq 7 FLOPs/request vs the always-fast / always-expensive
envelopes.

Overload and failure (docs/serving.md "Overload and failure semantics"):
``--preemption {none,youngest,fewest-tokens}`` evicts-and-replays a
victim row instead of stalling when an over-subscribed KV arena
(``--kv-blocks``) runs dry; ``--deadline SEC`` gives every request an
arrival-relative completion deadline and turns on load shedding;
``--launch-retries`` / ``--retry-backoff`` bound the transient-failure
retry wrapper; ``--inject-faults SPEC`` attaches a deterministic
:class:`repro.serving.faults.FaultPlan` (pool shrinkage, escalation
storms, launch failures, slow ticks — see that module for the grammar).
Ctrl-C prints the partial metrics summary and still flushes
``--trace-out``.

Observability: ``--trace-out trace.json`` records every request's
lifecycle (QUEUED -> PREFILL -> DECODE -> ESCALATED -> DONE) and every
tick's engine phases (admit / plan / launch / device_get / gate /
finish) as a Chrome-trace timeline loadable at https://ui.perfetto.dev;
``--metrics-interval 5`` prints a streaming snapshot line every 5
engine-clock seconds; ``--jax-profile DIR`` captures a jax.profiler
trace with named per-tier launch annotations.  See docs/serving.md.
"""
from __future__ import annotations

import argparse
import hashlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import bigram_lm
from repro.models import init_params
from repro.launch.mesh import make_tier_meshes
from repro.serving import CascadeEngine, FaultPlan, TierSpec, Tracer
from repro.serving.engine import VirtualClock, WallClock
from repro.serving.observability import profile_window


def parse_mesh_shape(s: str):
    """'4x2' -> (data=4, model=2); bare '4' means data-only."""
    data, _, model = s.lower().partition("x")
    return int(data), int(model or 1)


def tier_meshes(args, num_tiers: int):
    """Per-tier meshes from ``--tier-mesh`` (None: unmeshed tiers).  One
    shape is broadcast to every tier; otherwise one per tier."""
    if not args.tier_mesh:
        return [None] * num_tiers
    shapes = [parse_mesh_shape(s) for s in args.tier_mesh]
    if len(shapes) == 1:
        shapes = shapes * num_tiers
    if len(shapes) != num_tiers:
        raise ValueError(f"--tier-mesh takes 1 or {num_tiers} shapes, "
                         f"got {len(shapes)}")
    return make_tier_meshes(shapes)


def build_engine(args, clock=None, tracer=None):
    fast_cfg = get_config(args.fast, args.variant)
    exp_cfg = get_config(args.expensive, args.variant)
    fast_params = init_params(fast_cfg, jax.random.PRNGKey(args.seed),
                              jnp.float32)
    exp_seed = getattr(args, "expensive_seed", None)
    exp_params = init_params(
        exp_cfg,
        jax.random.PRNGKey(args.seed + 1 if exp_seed is None else exp_seed),
        jnp.float32)
    gate_kw = ({"deltas": [args.delta]} if args.delta is not None
               else {"escalation_budget": args.escalation_budget})
    meshes = tier_meshes(args, 2)
    shard_params = bool(getattr(args, "shard_params", False))
    engine = CascadeEngine(
        [TierSpec(args.fast, fast_cfg, fast_params, mesh=meshes[0],
                  shard_params=shard_params),
         TierSpec(args.expensive, exp_cfg, exp_params, mesh=meshes[1],
                  shard_params=shard_params)],
        slots=args.slots, prompt_len=args.prompt_len, gen_len=args.gen_len,
        use_gate_kernel=not args.no_gate_kernel,
        use_paged_kv=not args.dense_kv, kv_block_size=args.kv_block_size,
        kv_blocks=args.kv_blocks,
        use_chunked_prefill=False if (args.no_chunked_prefill
                                      or args.dense_kv) else None,
        prefill_chunk=args.prefill_chunk,
        prefill_token_budget=args.prefill_token_budget,
        use_unified_step=False if getattr(args, "split_step", False)
        else None,
        use_ragged_step=getattr(args, "ragged_step", None),
        flat_buckets=getattr(args, "flat_buckets", None),
        prefix_cache=bool(getattr(args, "prefix_cache", False)),
        speculation_k=getattr(args, "speculate", 0) or 0,
        spec_delta=getattr(args, "spec_delta", None),
        clock=clock if clock is not None else WallClock(),
        tracer=tracer,
        profile_annotations=bool(getattr(args, "jax_profile", None)),
        preemption_policy=getattr(args, "preemption", "none"),
        launch_retries=getattr(args, "launch_retries", 2),
        retry_backoff=getattr(args, "retry_backoff", 0.02),
        faults=(FaultPlan.parse(args.inject_faults)
                if getattr(args, "inject_faults", None) else None),
        **gate_kw)
    return engine, min(fast_cfg.vocab_size, exp_cfg.vocab_size)


def poisson_arrivals(n: int, rate: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def sample_lengths(dist: str, n: int, max_len: int, min_len: int,
                   seed: int) -> np.ndarray:
    """Per-request prompt lengths in [min_len, max_len].

    uniform   — every prompt at max_len (the legacy uniform workload)
    lognormal — median ~ max_len/4, σ=0.8: the heavy right tail of chat /
                search traffic (most prompts short, a few near the cap)
    bimodal   — half short (~max_len/8), half long (~0.8·max_len): the
                mixed short-query + long-document pattern
    """
    if dist == "uniform":
        return np.full(n, max_len, np.int64)
    rng = np.random.default_rng(seed + 1_000_003)
    if dist == "lognormal":
        lens = rng.lognormal(mean=np.log(max(max_len / 4.0, 1.0)),
                             sigma=0.8, size=n)
    elif dist == "bimodal":
        short = rng.normal(max_len / 8.0, max_len / 16.0, size=n)
        long = rng.normal(0.8 * max_len, max_len / 10.0, size=n)
        lens = np.where(rng.random(n) < 0.5, short, long)
    else:
        raise ValueError(f"unknown length distribution {dist!r}")
    return np.clip(np.rint(lens), min_len, max_len).astype(np.int64)


def apply_shared_prefix(prompts: np.ndarray, lengths: np.ndarray,
                        frac: float, vocab: int, seed: int) -> np.ndarray:
    """Overwrite the first ``frac``·length tokens of every prompt with one
    shared base sequence (system-prompt traffic); the tail stays unique.
    ``frac=0`` is the identity, ``frac=1`` makes prompts pure prefixes of
    each other (maximal sharing)."""
    if not frac:
        return prompts
    if not 0.0 <= frac <= 1.0:
        raise ValueError(f"--shared-prefix-frac must be in [0, 1], "
                         f"got {frac}")
    base = bigram_lm(num_seqs=1, seq_len=prompts.shape[1], vocab=vocab,
                     seed=seed + 7_777_777)[0]
    out = prompts.copy()
    for i, n in enumerate(lengths):
        k = int(frac * int(n))
        out[i, :k] = base[:k]
    return out


def stream_checksum(engine) -> str:
    """Order-independent digest of every request's final (tier, state,
    token stream) — two runs serving the same workload bit-identically
    agree on it regardless of internal scheduling (the cache-A/B and
    sharded-parity oracle)."""
    h = hashlib.sha256()
    for req in sorted(engine.requests, key=lambda r: r.rid):
        h.update(f"{req.rid}:{req.tier}:{req.state.name}:".encode())
        h.update(np.asarray(req.tokens, np.int64).tobytes())
        h.update(b"|")
    return h.hexdigest()


def snapshot_line(snap: dict) -> str:
    """One-line periodic progress record (``--metrics-interval``)."""
    esc = "/".join(f"{r:.2f}" for r in snap["escalation_rates"])
    ece = "/".join("-" if np.isnan(e) else f"{e:.3f}"
                   for e in snap["gate_ece"])
    return (f"[t={snap['t']:.1f}] completed {snap['completed']}"
            f"/{snap['requests']}  steps {snap['steps']}  "
            f"esc [{esc}]  gate ece [{ece}]  "
            f"tick p50 {snap['tick_duration_p50']:.4f}")


def run(args, clock=None) -> dict:
    tracer = (Tracer(capacity=args.trace_ring)
              if getattr(args, "trace_out", None) else None)
    engine, vocab = build_engine(args, clock, tracer)
    # catches explicit flags AND the engine's auto-fallback to uniform
    # prefill (recurrent-state / frontend tiers, dense arena)
    if args.length_dist != "uniform" and not engine.chunked_prefill:
        raise ValueError(
            "mixed prompt lengths require chunked paged prefill, but the "
            "engine fell back to the uniform path (--no-chunked-prefill/"
            "--dense-kv given, or a tier carries recurrent state or a "
            "modality frontend) — use --length-dist uniform")
    prompts = bigram_lm(num_seqs=args.requests, seq_len=args.prompt_len,
                        vocab=vocab, seed=args.seed)
    lengths = sample_lengths(args.length_dist, args.requests,
                             args.prompt_len, args.min_prompt_len,
                             args.seed)
    prompts = apply_shared_prefix(
        prompts, lengths, getattr(args, "shared_prefix_frac", 0.0),
        vocab, args.seed)
    arrivals = poisson_arrivals(args.requests, args.rate, args.seed)
    # warmup compiles every tier and then resets the clock, so arrival
    # timestamps are relative to the start of serving, not construction
    engine.warmup()
    ddl = getattr(args, "deadline", None)
    for p, n, t in zip(prompts, lengths, arrivals):
        engine.submit(p[:int(n)], arrival_time=float(t),
                      deadline=None if ddl is None else float(t) + ddl)
    interval = getattr(args, "metrics_interval", None)
    on_snap = ((lambda s: print(snapshot_line(s)))
               if interval is not None else None)
    profile_dir = getattr(args, "jax_profile", None)
    interrupted = False
    with profile_window(profile_dir):
        try:
            summary = engine.run(metrics_interval=interval,
                                 on_snapshot=on_snap)
        except KeyboardInterrupt:
            # graceful stop: report what completed and still flush the
            # trace below, instead of dying with a bare traceback
            interrupted = True
            summary = engine.metrics.summary()
            print(f"\ninterrupted at t={engine.clock.now():.2f} — partial "
                  f"summary ({summary['completed']}/{summary['requests']} "
                  "completed)")
    summary["interrupted"] = interrupted
    trace_out = getattr(args, "trace_out", None)
    if trace_out:
        n_events = tracer.export(trace_out)
        summary["trace_events"] = n_events
        summary["trace_dropped"] = tracer.dropped
        print(f"wrote {n_events} trace events to {trace_out}"
              + (f" ({tracer.dropped} dropped)" if tracer.dropped else ""))
    summary["rate"] = args.rate
    # realized offered load: completions can never beat this in an
    # open-loop run (makespan >= arrival span), a sanity bound on
    # the reported throughput
    summary["offered_rate"] = (
        args.requests / float(arrivals[-1] - arrivals[0])
        if args.requests > 1 and arrivals[-1] > arrivals[0]
        else float("nan"))
    summary["slots"] = args.slots
    summary["gen_len"] = args.gen_len
    summary["length_dist"] = args.length_dist
    summary["max_prompt_len"] = args.prompt_len
    summary["prefill_chunk"] = (engine.prefill_chunk
                                if engine.chunked_prefill else None)
    summary["chunked_prefill"] = engine.chunked_prefill
    summary["unified_step"] = engine.unified_step
    summary["ragged_step"] = engine.ragged_step
    summary["flat_buckets"] = [rt.flat_buckets if rt.ragged else None
                               for rt in engine.runtimes]
    # compiled-program accounting: warmed vs launched widths per tier
    # (mid_run_recompiles nonzero means a tick launched a width warmup
    # never compiled — the failure mode the bucketed layout eliminates)
    summary["compiled_programs"] = engine.compile_stats()
    summary["mid_run_recompiles"] = sum(
        len(c["mid_run_recompiles"]) for c in summary["compiled_programs"])
    summary["admitted_tokens_by_tier"] = \
        list(engine.scheduler.admitted_tokens)
    summary["escalation_budget"] = (None if args.delta is not None
                                    else args.escalation_budget)
    summary["delta"] = [engine.scheduler.delta(g)
                        for g in range(len(engine.scheduler.gates))]
    # block-paged KV arena accounting (high-water = blocks actually
    # mapped at peak, the number the paged arena saves vs dense; sharded
    # pools additionally report per-data-shard high-water)
    # overload & failure knobs, for the BENCH json and the report line
    summary["speculation_k"] = engine.speculation_k
    summary["spec_delta"] = engine.spec_delta
    summary["preemption_policy"] = engine.preemption_policy
    summary["deadline"] = ddl
    if engine.faults is not None:
        summary["faults"] = engine.faults.describe()
        summary["fault_events"] = len(engine.faults.log)
    summary["kv_arena"] = engine.memory_stats()
    # prefix-cache A/B provenance: config knobs plus an order-independent
    # digest of every final token stream (bit-identity oracle)
    summary["prefix_cache_enabled"] = engine.prefix_cache
    summary["shared_prefix_frac"] = float(
        getattr(args, "shared_prefix_frac", 0.0) or 0.0)
    summary["stream_checksum"] = stream_checksum(engine)
    # sharded serving: per-tier mesh layout (None entries: single-device)
    summary["tier_meshes"] = engine.mesh_topology()
    summary["device_count"] = jax.device_count()
    return summary


def report(s: dict) -> None:
    unit = "s"
    print(f"served {s['completed']}/{s['requests']} requests "
          f"in {s['elapsed']:.2f}{unit} over {s['steps']} engine steps "
          f"(rate {s['rate']}/s, {s['slots']} slots/tier)")
    if any(t["mesh"] for t in s.get("tier_meshes", [])):
        print("  meshes " + "  ".join(
            f"{t['tier']}={t['mesh']}" for t in s["tier_meshes"]))
    print(f"  latency  p50 {s['latency_p50']:.3f}{unit}  "
          f"p95 {s['latency_p95']:.3f}{unit}   "
          f"ttft p50 {s['ttft_p50']:.3f}{unit}  p95 {s['ttft_p95']:.3f}{unit}")
    if s.get("chunked_prefill"):
        buckets = "  ".join(f"{b}:{v:.3f}{unit}" for b, v in
                            s["ttft_p50_by_prompt_bucket"].items())
        print(f"  prompts {s['length_dist']} (mean {s['prompt_len_mean']:.1f}"
              f"/{s['max_prompt_len']} tok, chunk {s['prefill_chunk']})  "
              f"live-token ratio {s['prefill_live_token_ratio']:.3f}")
        print(f"  ttft p50 by prompt bucket  {buckets}")
    print(f"  throughput {s['throughput']:.2f} req/{unit}   "
          f"tier utilization "
          + "  ".join(f"{n}={u:.2f}" for n, u in
                      zip(s['tier_names'], s['tier_utilization'])))
    # realized launch efficiency: compiled-program dispatches and
    # blocking device_gets per engine tick, per tier (the unified
    # token-batch path's budget is one of each per active tier per tick)
    mode = ("ragged" if s.get("ragged_step")
            else "unified" if s.get("unified_step") else "split")
    print(f"  launches/tick [{mode}] "
          + "  ".join(f"{n}={l:.2f}" for n, l in
                      zip(s["tier_names"], s["launches_per_tick"]))
          + "   host-syncs/tick "
          + "  ".join(f"{n}={h:.2f}" for n, h in
                      zip(s["tier_names"], s["host_syncs_per_tick"])))
    if s.get("step_processed_tokens"):
        cp = s.get("compiled_programs") or []
        progs = "  ".join(f"{c['tier']}={c['compiled_programs']}"
                          for c in cp)
        recomp = s.get("mid_run_recompiles", 0)
        print(f"  token slots  live {s['step_live_tokens']}"
              f"/{s['step_processed_tokens']} processed "
              f"(wasted-slot ratio {s['wasted_slot_ratio']:.3f})   "
              f"compiled programs {progs}"
              + (f"   MID-RUN RECOMPILES {recomp}" if recomp else ""))
    overloaded = (s.get("shed") or s.get("failed") or s.get("preemptions")
                  or s.get("launch_retries")
                  or s.get("preemption_policy", "none") != "none"
                  or s.get("interrupted"))
    if overloaded:
        cons = s.get("conservation", {})
        print(f"  overload [{s.get('preemption_policy', 'none')}]  "
              f"shed {s.get('shed', 0)} "
              f"(rate {s.get('shed_rate', 0.0):.3f})  "
              f"preempted {s.get('preemptions', 0)} "
              f"(replayed {s.get('replayed_tokens', 0)} tok)  "
              f"failed {s.get('failed', 0)}  "
              f"launch retries {s.get('launch_retries', 0)}  "
              "conservation "
              + ("ok" if cons.get("ok")
                 else ("interrupted" if s.get("interrupted")
                       else f"VIOLATED ({cons})")))
    pc = s.get("prefix_cache") or {}
    if s.get("prefix_cache_enabled") and pc.get("lookups"):
        shared_hw = sum(t.get("kv_shared_high_water_blocks", 0)
                        for t in s.get("kv_arena", [])
                        if isinstance(t, dict))
        print(f"  prefix cache  hit rate {pc['hit_rate']:.2f} "
              f"({pc['hits']}/{pc['lookups']} admissions)  "
              f"cached tokens {pc['cached_tokens']} "
              f"({pc['cached_token_frac']:.2f} of prompt tokens)  "
              f"shared-block hw {shared_hw}")
    sp = s.get("speculation") or {}
    if s.get("speculation_k") and sp.get("drafted"):
        print(f"  speculation k={s['speculation_k']}  "
              f"accept rate {sp['accept_rate']:.2f} "
              f"({sp['accepted']}/{sp['drafted']} drafts, "
              f"{sp['rolled_back']} rolled back)")
    rates = ", ".join(f"{r:.3f}" for r in s["escalation_rates"])
    deltas = ", ".join(f"{d:.4f}" for d in s["delta"])
    target = ("" if s.get("escalation_budget") is None
              else f" (budget target {s['escalation_budget']:.3f})")
    print(f"  escalation rate [{rates}] at δ=[{deltas}]{target}")
    cal = s.get("gate_calibration") or []
    if cal:
        # streaming calibration against the escalation-outcome proxy
        # (cheap-vs-expensive token agreement on escalated traffic)
        def _f(x, spec=".3f"):
            return "-" if x is None or np.isnan(x) else format(x, spec)
        print("  gate calibration "
              + "  ".join(f"g{g['gate']}: ece {_f(g['ece'])} "
                          f"agree {_f(g['agreement_rate'], '.2f')} "
                          f"({g['outcomes']} outcomes)" for g in cal))
    print(f"  Eq7 FLOPs/request: cascade {s['flops_per_request_cascade']:.3e} "
          f"(always-fast {s['flops_per_request_always_fast']:.3e}, "
          f"always-expensive {s['flops_per_request_always_expensive']:.3e})")
    if s["flops_per_request_cascade"] \
            < s["flops_per_request_always_expensive"]:
        print("  cascade < always-expensive ✓")


def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fast", default="gemma3-1b")
    ap.add_argument("--expensive", default="phi4-mini-3.8b")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--slots", type=int, default=8,
                    help="KV slot pool size per tier")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="maximum prompt length (chunked prefill); exact "
                         "length under --no-chunked-prefill/--dense-kv")
    ap.add_argument("--min-prompt-len", type=int, default=1)
    ap.add_argument("--length-dist", default="uniform",
                    choices=("uniform", "lognormal", "bimodal"),
                    help="per-request prompt length distribution over "
                         "[min-prompt-len, prompt-len]")
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="prompt tokens a row advances per tick "
                         "(chunked paged prefill)")
    ap.add_argument("--prefill-token-budget", type=int, default=None,
                    help="prompt tokens admitted per tier per tick "
                         "(default slots * prefill-chunk)")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="uniform one-shot packed prefill (the chunked "
                         "path's bit-exactness oracle)")
    ap.add_argument("--split-step", action="store_true",
                    help="legacy split chunk+decode launches instead of "
                         "the unified mixed token-batch program (the "
                         "launch-count A/B escape hatch; default: unified "
                         "on paged attention-only tiers)")
    ap.add_argument("--ragged-step", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="ragged flat [1, W] token-batch layout inside "
                         "unified execution: live tokens pack "
                         "contiguously at a bucketed width, so a tick's "
                         "compute is O(live tokens).  --no-ragged-step "
                         "keeps the padded [slots, width] mixed program "
                         "(the bit-identical A/B baseline).  Default: "
                         "ragged whenever unified execution is on")
    ap.add_argument("--flat-buckets", type=int, nargs="*", default=None,
                    metavar="W",
                    help="compiled flat widths for --ragged-step (default "
                         "powers of two from 8 up to slots*prefill-chunk; "
                         "widths > 16 must be multiples of the kernel's "
                         "16-token query tile, and the largest must cover "
                         "slots*prefill-chunk)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative cascade decoding: the cheap tier "
                         "drafts up to K tokens per escalated request per "
                         "tick and the expensive tier scores all drafted "
                         "positions in its one ragged launch, emitting "
                         "every accepted token (plus the bonus token) in "
                         "a single tick.  Streams stay bit-identical to "
                         "K=0 (greedy acceptance emits scoring-model "
                         "argmaxes only).  Needs the ragged step; K=0 "
                         "disables (the escalation-only oracle)")
    ap.add_argument("--spec-delta", type=float, default=None,
                    metavar="CONF",
                    help="confidence floor for *keeping* drafted tokens "
                         "(draft truncates at its first token below it); "
                         "default: the draft tier's calibrated gate "
                         "threshold δ")
    ap.add_argument("--delta", type=float, default=None,
                    help="fixed gate threshold (overrides the budget)")
    ap.add_argument("--escalation-budget", type=float, default=0.25,
                    help="target escalation rate; δ is calibrated online")
    ap.add_argument("--no-gate-kernel", action="store_true",
                    help="jnp confidence instead of the Pallas gate kernel")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV block (paged arena)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="KV arena size in blocks per tier (default: fully "
                         "provisioned slots*pages_per_row+1; smaller "
                         "over-subscribes, attention-only models)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted KV prefix sharing: index finished "
                         "prompt chunks per shard, admit later requests "
                         "with matching leading tokens straight past them "
                         "(copy-on-write past the shared prefix; needs "
                         "chunked paged prefill)")
    ap.add_argument("--shared-prefix-frac", type=float, default=0.0,
                    metavar="F",
                    help="overwrite the first F·length tokens of every "
                         "prompt with one shared base sequence (synthetic "
                         "system-prompt traffic for exercising "
                         "--prefix-cache); 0 leaves prompts unique")
    ap.add_argument("--dense-kv", action="store_true",
                    help="PR 1 dense one-page-per-request arena instead of "
                         "the block-paged arena + paged decode kernel")
    ap.add_argument("--tier-mesh", nargs="*", default=None,
                    metavar="DATAxMODEL",
                    help="per-tier mesh shapes, e.g. --tier-mesh 4x1 2x2: "
                         "each tier gets its own mesh over a contiguous "
                         "slice of jax.devices() (wrapping when tiers "
                         "overrun the host); rows + KV block pool shard "
                         "over the data axis.  One shape is broadcast to "
                         "both tiers; default: no mesh (single device)")
    ap.add_argument("--shard-params", action="store_true",
                    help="tensor-shard tier params over the mesh 'model' "
                         "axis (default: replicate params per tier)")
    ap.add_argument("--preemption", default="none",
                    choices=("none", "youngest", "fewest-tokens"),
                    help="evict-and-replay policy when an over-subscribed "
                         "KV arena (--kv-blocks) runs dry: youngest evicts "
                         "the newest row on a stalled shard, fewest-tokens "
                         "the least-progressed; none keeps the stall "
                         "behaviour.  Replayed streams are bit-identical "
                         "(greedy decode)")
    ap.add_argument("--deadline", type=float, default=None, metavar="SEC",
                    help="per-request completion deadline, relative to "
                         "arrival (engine-clock units); queued requests "
                         "past — or provably unable to meet — it are shed")
    ap.add_argument("--launch-retries", type=int, default=2,
                    help="bounded retries per launch/transfer on transient "
                         "errors before sacrificing one request")
    ap.add_argument("--retry-backoff", type=float, default=0.02,
                    metavar="SEC", help="initial retry backoff (doubles "
                         "per attempt)")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="deterministic fault plan, e.g. "
                         "'seed=7,shrink=5:0:8:40,storm=10-14:0,"
                         "launch=0.05' (see repro/serving/faults.py for "
                         "the grammar)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--expensive-seed", type=int, default=None,
                    help="param-init seed for the expensive tier "
                         "(default --seed + 1).  Setting it to --seed "
                         "with matching --fast/--expensive configs gives "
                         "identical tiers — the self-speculation "
                         "configuration the spec_ab benchmark arm uses "
                         "to measure --speculate at a known accept rate")
    ap.add_argument("--json", default=None,
                    help="also write the summary dict to this path")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON timeline of "
                         "the run: per-request lifecycle spans and "
                         "per-tick engine phases (load at ui.perfetto.dev)")
    ap.add_argument("--trace-ring", type=int, default=1 << 18,
                    help="trace ring-buffer capacity in events; oldest "
                         "events drop first (dropped count is reported)")
    ap.add_argument("--metrics-interval", type=float, default=None,
                    metavar="SEC",
                    help="print a streaming metrics snapshot (completions, "
                         "escalation, gate ECE, tick p50) every SEC "
                         "engine-clock seconds")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the serving loop "
                         "into DIR (adds named run_mixed/run_chunk/"
                         "run_step annotations; view in TensorBoard or "
                         "Perfetto)")
    ap.add_argument("--virtual-clock", action="store_true",
                    help="deterministic 1-tick-per-step clock (arrival "
                         "times are then in ticks, not seconds)")
    return ap


def main() -> None:
    args = make_parser().parse_args()
    clock = VirtualClock() if args.virtual_clock else None
    summary = run(args, clock)
    report(summary)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, default=float)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
