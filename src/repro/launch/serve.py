"""Cascade serving: batched requests through a fast LLM, escalation of
low-confidence sequences to an expensive LLM (the paper's system, Fig 1,
with LLMs as the members).

:func:`serve_cascade` is a thin compatibility wrapper: the decode loop is
driven by :class:`repro.serving.CascadeEngine` (continuous batching over
KV slot pools, per-request gating, packed escalation queues).  Flow per
request:

  1. fast tier: prefill prompt -> greedy decode `gen_len` tokens, per-token
     confidence from the fused gate (max softmax prob — the paper's conf).
  2. sequence confidence = aggregate of token confs (mean by default).
  3. sequences with conf <= δ are escalated: the expensive tier re-decodes
     them as dense packed sub-batches; Eq 7 cost accounting uses
     per-member FLOPs/token with N^exp = #escalated.

For request-level asynchronous serving (Poisson arrivals, latency
percentiles, escalation budgets) use ``repro.launch.serve_async``.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import confidence as conf_lib
from repro.data import bigram_lm
from repro.kernels import ops as kernel_ops
from repro.models import init_cache, init_params, transformer
from repro.serving import CascadeEngine, TierSpec
from repro.serving.engine import VirtualClock


@dataclass
class ServeStats:
    n: int
    n_exp: int
    flops_fast: float
    flops_exp: float

    @property
    def flops_cascade(self) -> float:
        """Eq 7 with FLOPs in place of MACs."""
        return self.flops_fast + (self.n_exp / max(self.n, 1)) * self.flops_exp


def greedy_decode(cfg, params, prompts, gen_len, *, use_gate_kernel=False):
    """prompts [B, P] int32.  Returns (tokens [B, gen_len], conf [B, gen_len])."""
    B, P = prompts.shape
    total = P + gen_len
    cache = init_cache(cfg, B, total, jnp.float32)

    batch = {"tokens": prompts}
    if cfg.frontend:
        batch["frontend_embeds"] = jnp.zeros(
            (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    logits, part_cache, _ = transformer.forward(params, cfg, batch,
                                                mode="prefill")

    def put(full, part):
        if full.shape == part.shape:
            return part.astype(full.dtype)
        return full.at[tuple(slice(0, s) for s in part.shape)].set(
            part.astype(full.dtype))

    cache = jax.tree.map(put, cache, part_cache)

    @jax.jit
    def step(tok, cache, pos):
        lg, new_cache = transformer.decode_step(params, cfg, tok, cache, pos)
        if use_gate_kernel:
            gate = kernel_ops.confidence_gate(lg[:, 0])
            nxt = gate["argmax"][:, None]
            c = gate["conf"]
        else:
            nxt = jnp.argmax(lg[:, -1], -1)[:, None]
            c = conf_lib.max_prob(lg[:, -1])
        return nxt.astype(jnp.int32), c, new_cache

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    confs, toks = [], []
    first_conf = conf_lib.max_prob(logits[:, -1])
    for t in range(gen_len):
        toks.append(tok)
        confs.append(first_conf if t == 0 else c)  # conf of the token emitted
        pos = jnp.full((B, 1), P + t, jnp.int32)
        tok, c, cache = step(tok, cache, pos)
    return jnp.concatenate(toks, 1), jnp.stack(confs, 1)


def serve_cascade(fast_arch="gemma3-1b", exp_arch="phi4-mini-3.8b", *,
                  variant="smoke", fast_variant=None, exp_variant=None,
                  batch=8, prompt_len=32, gen_len=16,
                  delta=0.5, seed=0, fast_params=None, exp_params=None,
                  use_gate_kernel=False, pack=False, verbose=True,
                  slots=None):
    """Compatibility wrapper over :class:`repro.serving.CascadeEngine`.

    All `batch` requests arrive at t=0 and are drained to completion;
    returns the old contract ``(out_tokens [B,G], seq_conf [B],
    ServeStats)``.  ``pack`` is accepted for backwards compatibility —
    the engine always packs escalations densely.  ``slots`` bounds the
    per-tier KV slot pools (default: `batch`, i.e. the old synchronous
    behaviour; smaller values exercise continuous batching).
    """
    del pack  # escalation is always packed by the engine
    fast_cfg = get_config(fast_arch,
                          variant if fast_variant is None else fast_variant)
    exp_cfg = get_config(exp_arch,
                         variant if exp_variant is None else exp_variant)
    vocab = min(fast_cfg.vocab_size, exp_cfg.vocab_size)

    key = jax.random.PRNGKey(seed)
    if fast_params is None:
        fast_params = init_params(fast_cfg, key, jnp.float32)
    if exp_params is None:
        exp_params = init_params(exp_cfg, jax.random.PRNGKey(seed + 1),
                                 jnp.float32)

    prompts = np.asarray(bigram_lm(num_seqs=batch, seq_len=prompt_len,
                                   vocab=vocab, seed=seed))

    t0 = time.time()
    engine = CascadeEngine(
        [TierSpec("fast", fast_cfg, fast_params),
         TierSpec("exp", exp_cfg, exp_params)],
        slots=batch if slots is None else slots,
        prompt_len=prompt_len, gen_len=gen_len, deltas=[delta],
        use_gate_kernel=use_gate_kernel, clock=VirtualClock())
    for p in prompts:
        engine.submit(p, arrival_time=0.0)
    engine.run()

    out_tokens = np.stack([np.asarray(r.tokens, np.int32)
                           for r in engine.requests])
    seq_conf = np.asarray([r.seq_conf_by_tier[0] for r in engine.requests],
                          np.float32)
    n_exp = engine.scheduler.gate_stats[0].escalated

    # Eq 7 accounting: FLOPs per generated token = 2 * active params
    flops_fast = 2.0 * fast_cfg.active_param_count() * gen_len
    flops_exp = 2.0 * exp_cfg.active_param_count() * gen_len
    stats = ServeStats(n=batch, n_exp=n_exp, flops_fast=flops_fast,
                       flops_exp=flops_exp)
    if verbose:
        print(f"served {batch} requests in {time.time()-t0:.1f}s: "
              f"escalated {n_exp}/{batch} (δ={delta})")
        print(f"  FLOPs/token: fast={flops_fast/gen_len:.3e} "
              f"exp={flops_exp/gen_len:.3e} "
              f"cascade={stats.flops_cascade/gen_len:.3e}")
    return jnp.asarray(out_tokens), jnp.asarray(seq_conf), stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", default="gemma3-1b")
    ap.add_argument("--expensive", default="phi4-mini-3.8b")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--delta", type=float, default=0.5)
    ap.add_argument("--gate-kernel", action="store_true",
                    help="use the Pallas confidence_gate (interpret on CPU)")
    ap.add_argument("--pack", action="store_true",
                    help="(compat flag; the engine always packs)")
    ap.add_argument("--slots", type=int, default=None,
                    help="per-tier KV slot pool size (default: batch)")
    args = ap.parse_args()
    serve_cascade(args.fast, args.expensive, variant=args.variant,
                  batch=args.batch, prompt_len=args.prompt_len,
                  gen_len=args.gen_len, delta=args.delta,
                  use_gate_kernel=args.gate_kernel, pack=args.pack,
                  slots=args.slots)


if __name__ == "__main__":
    main()
