"""Assigned input shapes and per-(arch × shape) input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for everything a step function takes
*except* params/opt-state (those come from repro.models.params /
launch.steps).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.models.cache import cache_shapes


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _bspec(mesh, ndim, batch_shardable: bool):
    axes = _batch_axes(mesh)
    lead = None
    if batch_shardable and axes:
        lead = axes if len(axes) > 1 else axes[0]
    return PartitionSpec(lead, *([None] * (ndim - 1)))


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ModelConfig, shape_name: str, mesh,
                dtype=jnp.bfloat16, seq_over_model: bool = False) -> dict:
    """ShapeDtypeStructs for one (arch × shape) pair on a mesh.

    train/prefill: {"tokens" [B,S] (+ "frontend_embeds")}.
    decode:        {"token" [B,1], "pos" [B,1], "cache": tree} — one new
                   token against a seq_len KV cache.  For batch=1
                   (long_500k) the cache sequence dim is sharded over the
                   data axis instead of batch (see models.cache).
    """
    s = SHAPES[shape_name]
    import math
    n_batch_axes = math.prod(
        dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        for a in _batch_axes(mesh)) if _batch_axes(mesh) else 1
    batch_shardable = s.global_batch % max(n_batch_axes, 1) == 0

    if s.kind in ("train", "prefill"):
        specs = {"tokens": _sds((s.global_batch, s.seq_len), jnp.int32, mesh,
                                _bspec(mesh, 2, batch_shardable))}
        if cfg.frontend:
            specs["frontend_embeds"] = _sds(
                (s.global_batch, cfg.frontend_len, cfg.frontend_dim), dtype,
                mesh, _bspec(mesh, 3, batch_shardable))
        return specs

    # decode
    shard_seq = not batch_shardable   # batch=1 -> sequence-parallel cache
    return {
        "token": _sds((s.global_batch, 1), jnp.int32, mesh,
                      _bspec(mesh, 2, batch_shardable)),
        "pos": _sds((s.global_batch, 1), jnp.int32, mesh,
                    _bspec(mesh, 2, batch_shardable)),
        "cache": cache_shapes(cfg, s.global_batch, s.seq_len, mesh=mesh,
                              dtype=dtype, shard_seq=shard_seq,
                              seq_over_model=seq_over_model),
    }
