"""Roofline terms from a compiled dry-run artifact (TPU v5e constants).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

cost_analysis() on the partitioned module reports *per-device* FLOPs and
bytes, and the HLO parser reports per-device wire bytes, so the per-chip
times are those values divided by the single-chip rates; the table also
re-derives the spec's global formulation (x chips on both sides — same
number) for the record.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Optional

from repro.launch import hlo as hlo_lib
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw measurements (per device)
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    collective_detail: dict
    # analytic
    model_flops: float           # 6*N*D (dense) / 6*N_active*D (MoE), global
    # derived times (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    useful_flops_ratio: float    # MODEL_FLOPS / (HLO_FLOPs*chips)
    memory_per_device_gb: float
    peak_memory_gb: Optional[float] = None
    note: str = ""

    def to_json(self):
        return json.dumps(asdict(self), indent=1)


def analyze(*, arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops: float,
            memory_stats=None, note: str = "") -> Roofline:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    stats = hlo_lib.collective_stats(hlo_text)
    wire = stats.total_wire_bytes

    t_c = flops / PEAK_FLOPS_BF16
    t_m = nbytes / HBM_BW
    t_x = wire / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)

    mem_gb = None
    arg_gb = 0.0
    if memory_stats is not None:
        arg = memory_stats.argument_size_in_bytes
        tmp = memory_stats.temp_size_in_bytes
        out = memory_stats.output_size_in_bytes
        alias = memory_stats.alias_size_in_bytes
        mem_gb = (arg + tmp + out - alias) / 1e9
        arg_gb = arg / 1e9

    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=nbytes,
        wire_bytes_per_chip=wire,
        collective_detail={"bytes_by_op": stats.bytes_by_op,
                           "count_by_op": stats.count_by_op},
        model_flops=model_flops,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        useful_flops_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        memory_per_device_gb=arg_gb,
        peak_memory_gb=mem_gb,
        note=note,
    )


def model_flops_for(cfg, shape, kind: str) -> float:
    """6*N*D rule.  Train counts fwd+bwd (6ND); prefill counts forward only
    (2ND); decode counts one token (2*N_active per token * batch)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
