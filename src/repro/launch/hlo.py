"""Post-partitioning HLO analysis: collective traffic extraction.

``compiled.as_text()`` is the SPMD-partitioned per-device module; every
cross-device transfer appears as an explicit collective op whose output
shape is per-device.  We sum output bytes per op kind and convert to
on-wire bytes with the standard ring factors:

    all-reduce         2(n-1)/n ~ 2x output size
    all-gather         (n-1)/n  ~ 1x
    reduce-scatter     (n-1)/n  ~ 1x
    all-to-all         (n-1)/n  ~ 1x
    collective-permute 1x
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<outs>[^=]*?)\s*(?P<op>" + "|".join(_COLLECTIVES) +
    r")(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return sum(_WIRE_FACTOR[k] * v for k, v in self.bytes_by_op.items())

    @property
    def total_raw_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic from post-SPMD HLO text.

    Skips `-done` ops (the payload was counted at `-start`) and
    get-tuple-element wrappers.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "get-tuple-element" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        if f"{m.group('op')}-done(" in line:
            continue
        b = _shape_bytes(m.group("outs"))
        op = m.group("op")
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + b
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


def op_histogram(hlo_text: str, top: int = 20):
    """Instruction-name histogram — handy for spotting remat recompute and
    layout-change churn during §Perf iterations."""
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*[^ ]+ ([a-z][a-z0-9-]*)\(", line)
        if m:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
