"""Step functions (train / prefill / serve) + their sharding trees.

These are the functions the dry-run lowers and a real deployment jits.
``make_train_step`` is standard next-token LM training (L_org + MoE aux);
``make_ltc_train_step`` is the paper's Eq 4 applied to the fast member of
a cascade pair with the expensive member frozen (see repro.core.losses).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig
from repro.core import losses
from repro.models import cache as cache_lib
from repro.models import params as params_lib
from repro.models import transformer
from repro.optim import get_optimizer


def make_optimizer(cfg: ModelConfig):
    if cfg.optimizer in ("sgd", "sgd_momentum"):
        return get_optimizer("sgd_momentum", momentum=0.9)
    if cfg.optimizer == "adamw":
        return get_optimizer("adamw", weight_decay=0.01)
    return get_optimizer("adafactor")


# --------------------------------------------------------------------------
# Train
# --------------------------------------------------------------------------


def lm_loss(params, cfg: ModelConfig, batch, chunked_ce: int = 0):
    labels = batch["tokens"][:, 1:]
    if chunked_ce:
        hidden, _, aux = transformer.forward(params, cfg, batch,
                                             mode="train",
                                             return_hidden=True)
        proj = transformer.lm_proj(params, cfg)
        l = losses.chunked_lm_loss(hidden[:, :-1], proj, labels,
                                   chunk=min(chunked_ce, labels.shape[1]))
    else:
        logits, aux = transformer.train_logits(params, cfg, batch)
        l = losses.cross_entropy(logits[:, :-1], labels)
    l = l + losses.moe_aux_loss(aux)
    return l, {"loss": l}


def make_train_step(cfg: ModelConfig, lr: float = 1e-3,
                    force_remat: bool = True, microbatches: int = 1,
                    chunked_ce: int = 0):
    # Activation checkpointing around the period scan body is the training
    # default: without it the scan saves every layer's attention/FFN
    # intermediates for backward (measured 138 GB/chip on gemma3 train_4k
    # — see EXPERIMENTS.md §Perf iteration 0).
    if force_remat and cfg.num_periods and not cfg.remat:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=True)
    opt = make_optimizer(cfg)

    def loss_fn(p, b):
        return lm_loss(p, cfg, b, chunked_ce=chunked_ce)

    if microbatches == 1:
        def train_step(params, opt_state, batch):
            (l, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            params, opt_state = opt.update(params, grads, opt_state, lr)
            return params, opt_state, m

        return train_step, opt

    # Gradient accumulation (§Perf hillclimb): activations, logits and
    # remat checkpoints scale with the live microbatch — M microbatches
    # cut the activation term ~M× for one extra grads-sized accumulator.
    from repro.models.sharding import shard_hint

    def train_step(params, opt_state, batch):
        M = microbatches

        def split(a):
            a = a.reshape(M, a.shape[0] // M, *a.shape[1:])
            return a

        mbs = jax.tree.map(split, batch)

        def body(acc, mb):
            mb = jax.tree.map(
                lambda a: shard_hint(a, "batch", *([None] * (a.ndim - 1))),
                mb)
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return acc, l

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        grads, ls = jax.lax.scan(body, zeros, mbs)
        grads = jax.tree.map(lambda g: g / M, grads)
        params, opt_state = opt.update(params, grads, opt_state, lr)
        return params, opt_state, {"loss": jnp.mean(ls)}

    return train_step, opt


def make_ltc_train_step(fast_cfg: ModelConfig, exp_cfg: ModelConfig,
                        *, w: float = 1.0, cost_c: float = 0.5,
                        lr: float = 1e-3):
    """Eq 4 for LM cascades: the frozen expensive model's forward runs on
    the same batch to supply the 1[exp wrong] indicator."""
    opt = make_optimizer(fast_cfg)

    def loss_fn(fast_params, exp_params, batch):
        fast_logits, aux = transformer.train_logits(fast_params, fast_cfg, batch)
        exp_logits, _ = transformer.train_logits(
            jax.lax.stop_gradient(exp_params), exp_cfg, batch)
        labels = batch["tokens"][:, 1:]
        l, m = losses.ltc_loss(fast_logits[:, :-1],
                               jax.lax.stop_gradient(exp_logits[:, :-1]),
                               labels, w=w, cost_c=cost_c)
        l = l + losses.moe_aux_loss(aux)
        return l, m

    def train_step(fast_params, opt_state, exp_params, batch):
        (l, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            fast_params, exp_params, batch)
        fast_params, opt_state = opt.update(fast_params, grads, opt_state, lr)
        return fast_params, opt_state, m

    return train_step, opt


# --------------------------------------------------------------------------
# Serve
# --------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, cache, _ = transformer.forward(params, cfg, batch,
                                               mode="prefill")
        return logits[:, -1:], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: next-token logits + updated cache + the cascade
    gate's confidence (max softmax prob) — the paper's conf, computed
    where the logits live."""

    def serve_step(params, token, pos, cache):
        logits, new_cache = transformer.decode_step(params, cfg, token,
                                                    cache, pos)
        conf = jnp.max(jax.nn.softmax(logits.astype(jnp.float32), -1), -1)
        return logits, conf, new_cache

    return serve_step


# --------------------------------------------------------------------------
# Sharding trees
# --------------------------------------------------------------------------


def opt_state_specs(opt_name: str, cfg: ModelConfig, mesh):
    """PartitionSpecs for the optimizer state, derived from param specs.

    sgd: mu mirrors params.  adamw: m, v mirror params.  adafactor:
    vr drops the last param dim, vc drops the second-to-last.
    """
    pspecs = params_lib.param_specs(cfg, mesh)

    if opt_name in ("sgd_momentum", "sgd"):
        return {"mu": pspecs, "step": PartitionSpec()}
    if opt_name == "adamw":
        return {"m": pspecs, "v": pspecs, "step": PartitionSpec()}

    def leaf(spec, decl):
        dims = tuple(spec)
        # pad dims with None to param rank
        nd = len(decl.shape)
        dims = dims + (None,) * (nd - len(dims))
        if nd >= 2:
            return {"vr": PartitionSpec(*dims[:-1]),
                    "vc": PartitionSpec(*(dims[:-2] + dims[-1:]))}
        return {"v": PartitionSpec(*dims)}

    decl = params_lib.declare_model(cfg)
    v = jax.tree.map(leaf, pspecs, decl,
                     is_leaf=lambda x: isinstance(x, PartitionSpec))
    return {"v": v, "step": PartitionSpec()}


def opt_state_shapes(opt, cfg: ModelConfig, mesh, dtype=jnp.float32):
    """ShapeDtypeStructs (sharded) for the optimizer state without
    materializing params: eval_shape over opt.init."""
    pshapes = params_lib.param_shapes(cfg, dtype=dtype, mesh=mesh)
    state_shape = jax.eval_shape(opt.init, pshapes)
    specs = opt_state_specs(opt.name, cfg, mesh)

    def attach(sds, spec):
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=jax.sharding.NamedSharding(mesh, spec))

    return jax.tree.map(attach, state_shape, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
