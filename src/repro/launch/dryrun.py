import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with no real allocation (ShapeDtypeStruct inputs).

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch gemma3-1b --shape train_4k [--multi-pod] [--out DIR]

Success criteria (deliverable e): ``.lower().compile()`` succeeds for the
(16,16) single-pod mesh and the (2,16,16) multi-pod mesh for every pair;
memory_analysis / cost_analysis / collective schedule recorded for
EXPERIMENTS.md §Dry-run and §Roofline.

The XLA_FLAGS line above MUST run before any other jax-touching import —
jax locks the device count on first init.  This file is the only place the
512-device platform is forced; tests and benches see the real device.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config, long_context_variant
from repro.launch import roofline as roofline_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh, num_chips
from repro.launch.shapes import SHAPES, input_specs
from repro.models import params as params_lib
from repro.models import sharding as sharding_lib


def config_for(arch: str, shape_name: str):
    """Resolve the config (long_500k uses the documented sliding-window
    variant for full-attention archs; see DESIGN.md)."""
    cfg = get_config(arch)
    note = ""
    if shape_name == "long_500k" and not cfg.supports_long_natively:
        cfg = long_context_variant(cfg)
        note = f"sliding-window variant (w={cfg.long_variant_window})"
    return cfg, note


def lower_cfg(cfg, shape_name: str, mesh, *, dtype=jnp.bfloat16,
              donate: bool = True):
    """Lower one step function for a concrete config."""
    shape = SHAPES[shape_name]
    pshapes = params_lib.param_shapes(cfg, dtype=dtype, mesh=mesh)
    inputs = input_specs(cfg, shape_name, mesh, dtype=dtype)

    with sharding_lib.set_mesh(mesh):
        if shape.kind == "train":
            train_step, opt = steps_lib.make_train_step(cfg)
            oshapes = steps_lib.opt_state_shapes(opt, cfg, mesh, dtype=jnp.float32)
            fn = jax.jit(train_step,
                         donate_argnums=(0, 1) if donate else ())
            lowered = fn.lower(pshapes, oshapes, inputs)
        elif shape.kind == "prefill":
            prefill_step = steps_lib.make_prefill_step(cfg)
            lowered = jax.jit(prefill_step).lower(pshapes, inputs)
        else:
            serve_step = steps_lib.make_serve_step(cfg)
            fn = jax.jit(serve_step, donate_argnums=(3,) if donate else ())
            lowered = fn.lower(pshapes, inputs["token"], inputs["pos"],
                               inputs["cache"])
    return lowered


def _terms(compiled):
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    from repro.launch.hlo import collective_stats
    st = collective_stats(hlo)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "wire": st.total_wire_bytes,
            "bytes_by_op": st.bytes_by_op,
            "count_by_op": st.count_by_op}


def corrected_costs(cfg, shape_name, mesh):
    """XLA cost_analysis counts while-loop (scan) bodies ONCE regardless of
    trip count (measured: scan of P matmuls reports 1/P of the unrolled
    FLOPs).  Correction: lower unrolled 1- and 2-period variants — both
    exact — and extrapolate linearly:

        per_period = T(2) - T(1);  T(P) = T(1) + (P-1) * per_period

    Exact because all periods are structurally identical.
    """
    import dataclasses as dc
    c1 = dc.replace(cfg, num_periods=1, unroll_periods=True)
    c2 = dc.replace(cfg, num_periods=2, unroll_periods=True)
    t1 = _terms(lower_cfg(c1, shape_name, mesh).compile())
    t2 = _terms(lower_cfg(c2, shape_name, mesh).compile())
    P = cfg.num_periods
    out = {}
    for k in ("flops", "bytes", "wire"):
        body = max(t2[k] - t1[k], 0.0)
        out[k] = t1[k] + (P - 1) * body
    # collective op counts, linearly extrapolated for the record
    out["bytes_by_op"] = {k: t1["bytes_by_op"].get(k, 0.0)
                          + (P - 1) * max(t2["bytes_by_op"].get(k, 0.0)
                                          - t1["bytes_by_op"].get(k, 0.0), 0.0)
                          for k in set(t1["bytes_by_op"]) | set(t2["bytes_by_op"])}
    out["count_by_op"] = {k: t1["count_by_op"].get(k, 0)
                          + (P - 1) * max(t2["count_by_op"].get(k, 0)
                                          - t1["count_by_op"].get(k, 0), 0)
                          for k in set(t1["count_by_op"]) | set(t2["count_by_op"])}
    return out


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = None, verbose: bool = True, correct: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg, note = config_for(arch, shape_name)
    t0 = time.time()
    lowered = lower_cfg(cfg, shape_name, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    shape = SHAPES[shape_name]
    # The roofline table is single-pod only (the multi-pod pass proves the
    # pod axis shards) — skip the 3-compile scan-cost correction there.
    if correct and cfg.num_periods > 2 and not multi_pod:
        terms = corrected_costs(cfg, shape_name, mesh)
        note = (note + "; " if note else "") + "scan-cost corrected"
    else:
        terms = _terms(compiled)
        if multi_pod:
            note = (note + "; " if note else "") + \
                "raw scan-counted costs (roofline is single-pod)"
    cost = {"flops": terms["flops"], "bytes accessed": terms["bytes"]}
    rl = roofline_lib.analyze(
        arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=num_chips(mesh), cost=cost, hlo_text="",
        model_flops=roofline_lib.model_flops_for(cfg, shape, shape.kind),
        memory_stats=mem, note=note)
    # overwrite collective fields with the corrected parse
    rl.collective_detail["bytes_by_op"] = terms["bytes_by_op"]
    rl.collective_detail["count_by_op"] = terms["count_by_op"]
    from repro.launch.mesh import ICI_BW
    rl = dataclasses.replace(
        rl, wire_bytes_per_chip=terms["wire"],
        t_collective=terms["wire"] / ICI_BW)
    terms_d = {"compute": rl.t_compute, "memory": rl.t_memory,
               "collective": rl.t_collective}
    rl = dataclasses.replace(rl, bottleneck=max(terms_d, key=terms_d.get))

    if verbose:
        print(f"[{arch} x {shape_name} @ {mesh_name}] "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops/chip={rl.flops_per_chip:.3e} "
              f"bytes/chip={rl.bytes_per_chip:.3e}")
        print(f"  collectives: {rl.collective_detail['count_by_op']} "
              f"wire_bytes/chip={rl.wire_bytes_per_chip:.3e}")
        print(f"  roofline: compute={rl.t_compute*1e3:.2f}ms "
              f"memory={rl.t_memory*1e3:.2f}ms "
              f"collective={rl.t_collective*1e3:.2f}ms "
              f"-> {rl.bottleneck}-bound "
              f"(useful-flops {rl.useful_flops_ratio:.2f})")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        rec = dataclasses.asdict(rl)
        rec["lower_s"] = t_lower
        rec["compile_s"] = t_compile
        rec["memory_analysis"] = repr(mem)
        path = os.path.join(out_dir,
                            f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the (2,16,16) 512-chip mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun",
                    help="JSON output dir")
    ap.add_argument("--keep-going", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip pairs whose JSON already exists in --out")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if args.resume and os.path.exists(os.path.join(
                        args.out, f"{arch}__{shape}__{mesh_name}.json")):
                    print(f"skip [{arch} x {shape} @ {mesh_name}] (exists)")
                    continue
                try:
                    run_pair(arch, shape, multi_pod=mp, out_dir=args.out)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"FAIL [{arch} x {shape} multi_pod={mp}]: {e}")
                    if not args.keep_going:
                        traceback.print_exc()
                        raise SystemExit(1)
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll dry-runs passed.")


if __name__ == "__main__":
    main()
