"""Training driver.

Runs real steps (CPU smoke scale or a real mesh): standard LM training or
LtC cascade training (Eq 4) of a fast arch against a frozen expensive
arch.

    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma3-1b --variant smoke --steps 50 --batch 8 --seq 128
    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma3-1b --expensive phi4-mini-3.8b --variant smoke ...
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save as save_ckpt
from repro.configs import get_config
from repro.data import Batches, bigram_lm
from repro.launch import steps as steps_lib
from repro.models import init_params


def run(arch: str, *, variant="smoke", steps=50, batch=8, seq=128,
        lr=1e-2, expensive=None, ltc_w=1.0, cost_c=0.5, seed=0,
        ckpt=None, exp_params=None, log_every=10, data_seed=0,
        return_losses=False, vocab=None, trigram_frac=0.3):
    cfg = get_config(arch, variant)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key, jnp.float32)

    tokens = bigram_lm(num_seqs=max(batch * 16, 256), seq_len=seq,
                       vocab=vocab or cfg.vocab_size, seed=data_seed,
                       trigram_frac=trigram_frac)
    it = iter(Batches({"tokens": tokens}, batch, seed=seed))

    extra = {}
    if cfg.frontend:
        extra["frontend_embeds"] = np.zeros(
            (batch, cfg.frontend_len, cfg.frontend_dim), np.float32)

    if expensive is None:
        train_step, opt = steps_lib.make_train_step(cfg, lr=lr)
        train_step = jax.jit(train_step)
        args_extra = ()
    else:
        exp_cfg = get_config(expensive, variant)
        if exp_params is None:
            exp_params = init_params(exp_cfg, jax.random.PRNGKey(seed + 1),
                                     jnp.float32)
        train_step, opt = steps_lib.make_ltc_train_step(
            cfg, exp_cfg, w=ltc_w, cost_c=cost_c, lr=lr)
        train_step = jax.jit(train_step)
        args_extra = (exp_params,)

    opt_state = opt.init(params)
    losses = []
    t0 = time.time()
    for i in range(steps):
        b = dict(next(it))
        b.update(extra)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, m = train_step(params, opt_state, *args_extra, b)
        losses.append(float(m["loss"] if "loss" in m else m["l_org"]))
        if log_every and (i + 1) % log_every == 0:
            print(f"step {i+1}: loss {losses[-1]:.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if ckpt:
        save_ckpt(ckpt, params, step=steps)
        print(f"saved {ckpt}")
    if return_losses:
        return params, losses
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--expensive", default=None,
                    help="train with the LtC loss against this frozen arch")
    ap.add_argument("--ltc-w", type=float, default=1.0)
    ap.add_argument("--cost-c", type=float, default=0.5)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    run(args.arch, variant=args.variant, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, expensive=args.expensive, ltc_w=args.ltc_w,
        cost_c=args.cost_c, ckpt=args.ckpt)


if __name__ == "__main__":
    main()
