"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 (paper-table config).

[arXiv:2501.kimi2]  61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384e top-8.  DeepSeek-V3-style: the first layer keeps a
dense FFN, all remaining layers are MoE.  head_dim = d_model/num_heads = 112
per the assigned table (the real model uses MLA; the assignment specifies
GQA, which we follow).
"""
from repro.configs.base import Attn, Dense, Layer, MoE, ModelConfig, register

_MOE = MoE(num_experts=384, top_k=8, d_ff=2048, capacity_factor=1.25)

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    d_model=7168,
    vocab_size=163840,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    head=(Layer(Attn(), Dense(d_ff=16384)),),   # dense first layer (DSv3 style)
    period=(Layer(Attn(), _MOE),),
    num_periods=60,
    remat=True,
    fsdp=True,
    optimizer="adafactor",
    source="arXiv:2501.kimi2",
))
