"""Config system: architecture descriptions as frozen dataclasses.

A model is described as ``head ++ period * num_periods ++ tail`` where each
element is a :class:`Layer` (mixer + ffn).  The repeated ``period`` is
executed with ``jax.lax.scan`` over stacked weights so the lowered HLO stays
small even for 80-layer models; ``head``/``tail`` are unrolled.

Every assigned architecture lives in its own module under ``repro.configs``
and registers a :class:`ModelConfig` via :func:`register`.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# --------------------------------------------------------------------------
# Block specs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Attn:
    """Grouped-query attention mixer.

    window: sliding-window size (None = full causal attention).
    rope:   'rope' | 'mrope' (multimodal 3-section rotary) | 'none'.
    """

    window: Optional[int] = None
    rope: str = "rope"
    kind: str = field(default="attn", init=False)


@dataclass(frozen=True)
class Mamba:
    """Mamba-1 selective SSM mixer (diagonal A, data-dependent dt/B/C)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    kind: str = field(default="mamba", init=False)


@dataclass(frozen=True)
class RWKV6:
    """RWKV-6 'Finch' time-mix: linear attention with data-dependent decay."""

    head_dim: int = 64
    decay_lora: int = 64
    kind: str = field(default="rwkv6", init=False)


@dataclass(frozen=True)
class Dense:
    """Dense FFN.  act: 'swiglu' | 'gelu' | 'rwkv_cmix' (squared-relu channel mix)."""

    d_ff: int
    act: str = "swiglu"
    kind: str = field(default="dense", init=False)


@dataclass(frozen=True)
class MoE:
    """Token-choice top-k mixture of experts (einsum dispatch, capacity-bounded)."""

    num_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    act: str = "swiglu"
    kind: str = field(default="moe", init=False)


@dataclass(frozen=True)
class Layer:
    mixer: object  # Attn | Mamba | RWKV6
    ffn: object    # Dense | MoE


# --------------------------------------------------------------------------
# Model config
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    vocab_size: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    head: Tuple[Layer, ...] = ()
    period: Tuple[Layer, ...] = ()
    num_periods: int = 0
    tail: Tuple[Layer, ...] = ()

    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6

    # Modality frontend stub (sanctioned): precomputed embeddings are inputs.
    frontend: Optional[str] = None   # 'vision' | 'audio' | None
    frontend_dim: int = 0            # dim of the precomputed embeddings
    frontend_len: int = 0            # number of frontend positions per sample

    # Early-exit ("model splitting") support: exit heads after these period
    # indices (0-based, exit fires after period i completes).
    early_exit_periods: Tuple[int, ...] = ()

    # Distribution / memory knobs consumed by the launcher.
    remat: bool = False              # jax.checkpoint around the period body
    fsdp: bool = False               # 2D (model x data) weight sharding
    unroll_periods: bool = False     # python-loop the periods (used by the
                                     # dry-run's scan-cost correction)
    optimizer: str = "adafactor"     # train-step optimizer for dry-run
    dtype: str = "bfloat16"

    # KV-cache quantization ('int8' | None) — beyond-paper serving
    # optimization (§Perf): halves the decode memory term vs bf16.
    kv_quant: Optional[str] = None

    # Shard k/v over the seq dim (model axis) in full-seq attention when
    # the kv heads can't absorb it, so the probs·v contraction
    # partial-sums instead of all-gathering the T-sharded probs.
    # Default True after §Perf iteration 4 (30x collective reduction on
    # starcoder2 train; baseline numbers preserved in EXPERIMENTS.md).
    kv_seq_hint: bool = True

    # long_500k policy (see DESIGN.md): archs whose attention state is
    # bounded run natively; full-attention archs use a documented
    # sliding-window variant built by `long_context_variant`.
    supports_long_natively: bool = False
    long_variant_window: int = 8192

    source: str = ""                 # citation for the architecture

    # ---- derived -----------------------------------------------------

    @property
    def layers(self) -> Tuple[Layer, ...]:
        return self.head + self.period * self.num_periods + self.tail

    @property
    def num_layers(self) -> int:
        return len(self.head) + len(self.period) * self.num_periods + len(self.tail)

    @property
    def attn_free(self) -> bool:
        return all(l.mixer.kind != "attn" for l in self.layers)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.d_model * self.vocab_size
        if self.frontend:
            n += self.frontend_dim * self.d_model
        for layer in self.layers:
            n += _mixer_params(self, layer.mixer) + _ffn_params(self, layer.ffn)
            n += 2 * self.d_model  # two RMSNorm scales
        n += self.d_model  # final norm
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k of num_experts experts)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.d_model * self.vocab_size
        for layer in self.layers:
            n += _mixer_params(self, layer.mixer)
            f = layer.ffn
            if f.kind == "moe":
                per = _ffn_params(self, f) / f.num_experts
                n += int(per * f.top_k)
            else:
                n += _ffn_params(self, f)
            n += 2 * self.d_model
        n += self.d_model
        return n


def _mixer_params(cfg: ModelConfig, m) -> int:
    d = cfg.d_model
    if m.kind == "attn":
        return d * cfg.num_heads * cfg.head_dim + 2 * d * cfg.num_kv_heads * cfg.head_dim \
            + cfg.num_heads * cfg.head_dim * d
    if m.kind == "mamba":
        d_in = m.expand * d
        dt_rank = math.ceil(d / 16)
        return (d * 2 * d_in            # in_proj (x, z)
                + m.d_conv * d_in       # depthwise conv
                + d_in * (dt_rank + 2 * m.d_state)  # x_proj
                + dt_rank * d_in + d_in            # dt_proj (+bias)
                + d_in * m.d_state + d_in          # A_log, D
                + d_in * d)             # out_proj
    if m.kind == "rwkv6":
        # r/k/v/g/o projections + decay lora + token-shift mixers (approx).
        return 5 * d * d + 2 * d * m.decay_lora + 6 * d
    raise ValueError(m.kind)


def _ffn_params(cfg: ModelConfig, f) -> int:
    d = cfg.d_model
    if f.kind == "dense":
        mats = 3 if f.act == "swiglu" else 2
        return mats * d * f.d_ff
    if f.kind == "moe":
        mats = 3 if f.act == "swiglu" else 2
        return d * f.num_experts + f.num_experts * mats * d * f.d_ff
    raise ValueError(f.kind)


# --------------------------------------------------------------------------
# Variants
# --------------------------------------------------------------------------


def _map_layers(layers, fn):
    return tuple(fn(l) for l in layers)


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Sliding-window variant for the long_500k shape (dense archs only).

    Replaces every full-attention mixer with a windowed one; archs that
    support long context natively are returned unchanged.
    """
    if cfg.supports_long_natively:
        return cfg
    w = cfg.long_variant_window

    def fix(layer: Layer) -> Layer:
        m = layer.mixer
        if m.kind == "attn" and m.window is None:
            m = replace(m, window=w)
        return Layer(m, layer.ffn)

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-swa",
        head=_map_layers(cfg.head, fix),
        period=_map_layers(cfg.period, fix),
        tail=_map_layers(cfg.tail, fix),
    )


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family: <=2 layers, d_model<=512, <=4 experts.

    Used by per-arch smoke tests that run a real forward/train step on CPU.
    """
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    num_heads = max(2, d_model // 64)
    num_kv = max(1, min(cfg.num_kv_heads, num_heads))
    # keep the GQA ratio flavour: kv strictly <= heads, divides heads
    while num_heads % num_kv:
        num_kv -= 1

    def fix(layer: Layer) -> Layer:
        m, f = layer.mixer, layer.ffn
        if m.kind == "mamba":
            m = replace(m, d_state=8)
        if m.kind == "rwkv6":
            m = replace(m, head_dim=32, decay_lora=16)
        if m.kind == "attn" and m.window is not None:
            m = replace(m, window=16)
        if f.kind == "moe":
            f = MoE(num_experts=4, top_k=min(2, f.top_k), d_ff=64,
                    capacity_factor=2.0, act=f.act)
        else:
            f = Dense(d_ff=min(f.d_ff, 512), act=f.act)
        return Layer(m, f)

    # two layers total, drawn from the period so every mixer kind the
    # family uses is exercised.
    src = (cfg.head + cfg.period + cfg.tail)
    kinds_seen, picked = set(), []
    for l in src:
        if l.mixer.kind not in kinds_seen or (len(picked) < 2 and l.ffn.kind == "moe"
                                              and not any(p.ffn.kind == "moe" for p in picked)):
            picked.append(l)
            kinds_seen.add(l.mixer.kind)
        if len(picked) == 2:
            break
    while len(picked) < 2:
        picked.append(src[0])

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        vocab_size=min(cfg.vocab_size, 512),
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        head=(),
        period=tuple(fix(l) for l in picked),
        num_periods=1,
        tail=(),
        frontend_dim=64 if cfg.frontend else 0,
        frontend_len=8 if cfg.frontend else 0,
        early_exit_periods=(),
        remat=False,
        fsdp=False,
    )


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, variant: Optional[str] = None) -> ModelConfig:
    cfg = _REGISTRY[name]
    if variant == "smoke":
        return smoke_variant(cfg)
    if variant == "long":
        return long_context_variant(cfg)
    if variant:
        raise ValueError(f"unknown variant {variant!r}")
    return cfg


def list_configs():
    return sorted(_REGISTRY)
