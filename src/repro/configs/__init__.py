"""Architecture registry.  Importing this package registers every assigned
architecture (plus the paper-faithful cascade classifier zoo in
``repro.models.classifier``)."""
from repro.configs.base import (Attn, Dense, Layer, Mamba, MoE, ModelConfig,
                                RWKV6, get_config, list_configs,
                                long_context_variant, register, smoke_variant)

# Assigned architectures (import order = registry order).
from repro.configs import (  # noqa: F401
    jamba_v0_1_52b,
    musicgen_large,
    phi4_mini_3_8b,
    starcoder2_7b,
    kimi_k2_1t_a32b,
    moonshot_v1_16b_a3b,
    qwen2_vl_72b,
    rwkv6_3b,
    granite_moe_3b_a800m,
    gemma3_1b,
)

ASSIGNED = (
    "jamba-v0.1-52b",
    "musicgen-large",
    "phi4-mini-3.8b",
    "starcoder2-7b",
    "kimi-k2-1t-a32b",
    "moonshot-v1-16b-a3b",
    "qwen2-vl-72b",
    "rwkv6-3b",
    "granite-moe-3b-a800m",
    "gemma3-1b",
)

__all__ = [
    "Attn", "Dense", "Layer", "Mamba", "MoE", "ModelConfig", "RWKV6",
    "get_config", "list_configs", "long_context_variant", "register",
    "smoke_variant", "ASSIGNED",
]
