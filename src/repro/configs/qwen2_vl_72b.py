"""Qwen2-VL-72B — VLM decoder with M-RoPE (3-section rotary).

[arXiv:2409.12191]  80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
The ViT vision encoder + projector is the sanctioned frontend stub:
`input_specs` provides precomputed patch embeddings (dim 1280, the ViT
output width); a learned projector maps them into d_model and they replace
the token embeddings at the leading `frontend_len` positions.  M-RoPE splits
head_dim into (temporal, height, width) = (16, 24, 24) rotary sections
[arXiv:2409.12191 §2.1].
"""
from repro.configs.base import Attn, Dense, Layer, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    d_model=8192,
    vocab_size=152064,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    period=(Layer(Attn(rope="mrope"), Dense(d_ff=29568, act="swiglu")),),
    num_periods=80,
    frontend="vision",
    frontend_dim=1280,
    frontend_len=1024,     # patches per image at the dry-run resolution
    remat=True,
    fsdp=True,
    source="arXiv:2409.12191",
))
