"""Moonlight-16B-A3B — MoE decoder (64 experts, top-6).

[hf:moonshotai/Moonlight-16B-A3B]  48L d_model=2048 16H (kv=16) expert
d_ff=1408, vocab=163840, MoE 64e top-6.  The assignment labels it [dense]
but specifies MoE fields; we build it as the MoE it is (noted in DESIGN.md).
First layer dense (DeepSeek-V3 style), d_ff = 4*2048? -> use 11264 (~8x
expert) following Moonlight's dense-layer sizing.
"""
from repro.configs.base import Attn, Dense, Layer, MoE, ModelConfig, register

_MOE = MoE(num_experts=64, top_k=6, d_ff=1408, capacity_factor=1.25)

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    d_model=2048,
    vocab_size=163840,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    head=(Layer(Attn(), Dense(d_ff=11264)),),
    period=(Layer(Attn(), _MOE),),
    num_periods=47,
    source="hf:moonshotai/Moonlight-16B-A3B",
))
