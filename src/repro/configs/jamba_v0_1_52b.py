"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887]  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Each 8-layer Jamba block has one attention layer (index 4 within the block,
per the paper's a/m ratio 1:7) and MoE replaces the dense FFN every other
layer (e=16, k=2).
"""
from repro.configs.base import Attn, Dense, Layer, Mamba, MoE, ModelConfig, register


def _layer(i: int) -> Layer:
    mixer = Attn() if i == 4 else Mamba(d_state=16, d_conv=4, expand=2)
    ffn = (MoE(num_experts=16, top_k=2, d_ff=14336)
           if i % 2 == 1 else Dense(d_ff=14336))
    return Layer(mixer, ffn)


CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    vocab_size=65536,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    period=tuple(_layer(i) for i in range(8)),
    num_periods=4,
    remat=True,
    fsdp=True,
    supports_long_natively=True,   # 28/32 layers are SSM; 4 attn layers' KV fits
    source="arXiv:2403.19887",
))
