"""Gemma-3 1B — dense decoder with 5:1 local:global attention.

[hf:google/gemma-3-1b-pt]  26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144.  head_dim=256 (Gemma uses head_dim decoupled from d_model).
Pattern: 5 sliding-window (512) layers then 1 global layer; 26 layers =
4 periods of 6 + 2 local tail layers.  Supports long_500k natively: only
~5 global layers hold a full-length KV cache and the model is small.
"""
from repro.configs.base import Attn, Dense, Layer, ModelConfig, register

_LOCAL = Layer(Attn(window=512), Dense(d_ff=6912, act="swiglu"))
_GLOBAL = Layer(Attn(), Dense(d_ff=6912, act="swiglu"))

CONFIG = register(ModelConfig(
    name="gemma3-1b",
    family="dense",
    d_model=1152,
    vocab_size=262144,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    period=(_LOCAL,) * 5 + (_GLOBAL,),
    num_periods=4,
    tail=(_LOCAL, _LOCAL),
    tie_embeddings=True,
    rope_theta=1e6,
    supports_long_natively=True,
    source="hf:google/gemma-3-1b-pt",
))
