"""RWKV-6 'Finch' 3B — attention-free RNN with data-dependent decay.

[arXiv:2404.05892]  32L d_model=2560 d_ff=8960 vocab=65536.  40 heads of
dim 64; channel-mix FFN uses squared-relu (rwkv_cmix).  O(1) decode state
-> runs long_500k natively.
"""
from repro.configs.base import Dense, Layer, ModelConfig, RWKV6, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    d_model=2560,
    vocab_size=65536,
    num_heads=40,          # time-mix heads (d_model / head_dim)
    num_kv_heads=40,
    head_dim=64,
    period=(Layer(RWKV6(head_dim=64), Dense(d_ff=8960, act="rwkv_cmix")),),
    num_periods=32,
    supports_long_natively=True,
    source="arXiv:2404.05892",
))
