"""StarCoder2-7B — dense GQA decoder with RoPE and non-gated FFN.

[arXiv:2402.19173]  32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from repro.configs.base import Attn, Dense, Layer, ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-7b",
    family="dense",
    d_model=4608,
    vocab_size=49152,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    period=(Layer(Attn(), Dense(d_ff=18432, act="gelu")),),
    num_periods=32,
    source="arXiv:2402.19173",
))
