"""Phi-4-mini 3.8B — dense RoPE/SwiGLU/GQA decoder.

[arXiv:2412.08905]  32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import Attn, Dense, Layer, ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    d_model=3072,
    vocab_size=200064,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    period=(Layer(Attn(), Dense(d_ff=8192, act="swiglu")),),
    num_periods=32,
    tie_embeddings=True,
    source="arXiv:2412.08905",
))
