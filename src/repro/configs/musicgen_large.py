"""MusicGen-large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284]  48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192
vocab=2048.  The EnCodec conv codec is the sanctioned frontend stub:
`input_specs` provides audio-token ids (and conditioning embeddings of
`frontend_dim`) directly.  MusicGen uses sinusoidal positions; we use RoPE
(noted hardware/impl adaptation — positional scheme is orthogonal to LtC).
"""
from repro.configs.base import Attn, Dense, Layer, ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    d_model=2048,
    vocab_size=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    period=(Layer(Attn(), Dense(d_ff=8192, act="gelu")),),
    num_periods=48,
    frontend="audio",
    frontend_dim=768,     # conditioning (T5-style) embedding dim, stubbed
    frontend_len=64,
    source="arXiv:2306.05284",
))
