"""Granite-3.0 MoE 3B-A800M — 40 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base family]  32L d_model=1536 24H
(GQA kv=8) expert d_ff=512 vocab=49155, MoE 40e top-8.  40 experts do not
divide the 16-way model axis; the expert dim is replicated and the expert
FFN hidden dim (512) is sharded instead (see models.params).
"""
from repro.configs.base import Attn, Layer, MoE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    d_model=1536,
    vocab_size=49155,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    period=(Layer(Attn(), MoE(num_experts=40, top_k=8, d_ff=512)),),
    num_periods=32,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
