"""Learning to Cascade (Enomoto & Eda, AAAI 2021) — core library.

The paper's contribution as composable pieces:

  * losses       — Eq 3 (L_casc), Eq 4 (LtC), Eq 5/6 (M-element chains)
  * cascade      — Eq 1/2/7 metrics + offline/online cascade executors
  * confidence   — conf scores (max-prob is the paper's choice)
  * calibration  — the comparison baselines: temperature scaling, ConfNet,
                   IDK heads; ECE
  * thresholds   — δ search policies on the validation split
"""
from repro.core import calibration, cascade, confidence, losses, thresholds  # noqa: F401
from repro.core.cascade import CascadeExecutor, Member, evaluate_cascade, two_element_metrics
from repro.core.losses import (cascade_loss, cross_entropy, ltc_chain_loss,
                               ltc_loss, moe_aux_loss)
from repro.core.thresholds import best_accuracy_delta, min_cost_delta

__all__ = [
    "calibration", "cascade", "confidence", "losses", "thresholds",
    "CascadeExecutor", "Member", "evaluate_cascade", "two_element_metrics",
    "cascade_loss", "cross_entropy", "ltc_chain_loss", "ltc_loss",
    "moe_aux_loss", "best_accuracy_delta", "min_cost_delta",
]
