"""Cascade inference executor and metrics (paper §3, Eqs 1, 2, 7).

Two ways to use it:

  * Offline / evaluation: you already have every member's predictions on a
    dataset — :func:`evaluate_cascade` computes Acc^casc, N^exp and
    MACs^casc for a δ (or a vector of δs) without re-running the models.
    This is exactly how the paper evaluates (predictions are collected
    once; δ is swept on the validation split).
  * Online serving: :class:`CascadeExecutor` routes a live batch through
    member predict functions, only invoking member m+1 on the sub-batch
    whose confidence fell below δ_m (computed densely with masking under
    jit — shapes stay static, cost accounting reflects true escalations).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import confidence as C


@dataclass(frozen=True)
class Member:
    """One cascade element.  cost = MACs (or FLOPs) per sample."""
    name: str
    cost: float
    predict: Optional[Callable] = None   # batch -> logits (online mode)


# --------------------------------------------------------------------------
# Offline evaluation (paper Eqs 1, 2, 7) — vectorized over thresholds
# --------------------------------------------------------------------------


def evaluate_cascade(confs, corrects, costs, deltas):
    """Generic M-element cascade evaluation.

    confs:    [M-1, N] confidence of members 0..M-2 (the last member has no
              gate).
    corrects: [M, N]  1/0 correctness of each member's prediction.
    costs:    [M]     per-sample cost of each member.
    deltas:   [M-1] or [D, M-1] thresholds (broadcasts over a sweep).

    Returns dict with acc [D], cost [D], frac_used [D, M] (fraction of
    samples that *ran* each member), n_exp [D, M-1] (Eq 1 per gate).
    """
    confs = jnp.asarray(confs, jnp.float32)
    corrects = jnp.asarray(corrects, jnp.float32)
    costs = jnp.asarray(costs, jnp.float32)
    deltas = jnp.atleast_2d(jnp.asarray(deltas, jnp.float32))  # [D, M-1]
    M, N = corrects.shape

    def one(delta):
        active = jnp.ones((N,), jnp.float32)        # sample still cascading
        acc = jnp.zeros((N,), jnp.float32)
        total_cost = 0.0
        frac_used = []
        n_exp = []
        for m in range(M):
            frac_used.append(jnp.mean(active))
            total_cost = total_cost + jnp.mean(active) * costs[m]
            if m < M - 1:
                stop = active * (confs[m] > delta[m]).astype(jnp.float32)
                escalate = active - stop
                n_exp.append(jnp.sum(escalate))
                acc = acc + stop * corrects[m]
                active = escalate
            else:
                acc = acc + active * corrects[m]
        return {"acc": jnp.mean(acc), "cost": total_cost,
                "frac_used": jnp.stack(frac_used),
                "n_exp": jnp.stack(n_exp) if n_exp else jnp.zeros((0,))}

    out = jax.vmap(one)(deltas)
    return out


def two_element_metrics(conf, fast_correct, exp_correct, macs_fast,
                        macs_exp, delta):
    """Paper's two-element special case.  Returns (Acc^casc, MACs^casc, N^exp)
    per Eqs 2, 7, 1."""
    out = evaluate_cascade(conf[None, :],
                           jnp.stack([fast_correct, exp_correct]),
                           jnp.array([macs_fast, macs_exp]),
                           jnp.reshape(delta, (-1, 1)))
    d = jnp.ndim(delta)
    sq = (lambda x: x[0]) if d == 0 else (lambda x: x)
    return sq(out["acc"]), sq(out["cost"]), sq(out["n_exp"][:, 0])


# --------------------------------------------------------------------------
# Online executor
# --------------------------------------------------------------------------


class CascadeExecutor:
    """Run a live cascade over members with per-gate thresholds.

    Every member's ``predict`` runs on the full (static-shape) batch but
    only escalated rows are *accounted* (and, on a real deployment, only
    those rows would be sent — the escalation mask is returned so a serving
    layer can pack them; see repro.launch.serve for the packed version).
    """

    def __init__(self, members: Sequence[Member], deltas: Sequence[float],
                 conf_kind: str = "max_prob"):
        assert len(deltas) == len(members) - 1
        self.members = tuple(members)
        self.deltas = tuple(float(d) for d in deltas)
        self.conf_kind = conf_kind

    def __call__(self, batch):
        """Returns (predictions [B], info dict)."""
        logits0 = self.members[0].predict(batch)
        preds = jnp.argmax(logits0, -1)
        active = jnp.ones(preds.shape, jnp.float32)
        cost = jnp.full(preds.shape, self.members[0].cost, jnp.float32)
        escalations = []
        for m, member in enumerate(self.members[1:]):
            conf = C.score(logits0, self.conf_kind)
            esc = active * (conf <= self.deltas[m]).astype(jnp.float32)
            escalations.append(esc)
            logits1 = member.predict(batch)
            preds = jnp.where(esc > 0, jnp.argmax(logits1, -1), preds)
            cost = cost + esc * member.cost
            active = esc
            logits0 = logits1
        return preds, {"cost": cost, "escalated": escalations}
