"""Production cascade-serving orchestrator.

Wraps M generate functions (fast -> expensive) behind the paper's
confidence gates and accounts every request with Eq 1/2/7 bookkeeping.
Unlike :class:`repro.core.cascade.CascadeExecutor` (dense offline
evaluation), this layer:

  * packs escalated requests into dense sub-batches before invoking the
    next member (what actually crosses the pod axis on a deployment),
  * aggregates running statistics across batches (escalation rate per
    gate, realized cost, per-member utilization),
  * supports δ chosen from a target escalation budget on calibration
    traffic (:func:`delta_for_escalation_rate`) in addition to fixed δ.

Members expose ``generate(prompts) -> (outputs, seq_conf)`` where
``seq_conf`` is the aggregated sequence confidence (see
repro.core.confidence.sequence_confidence); the last member's confidence
is ignored (no gate after it).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class ServingMember:
    name: str
    generate: Callable          # prompts [B, P] -> (outputs [B, G], conf [B])
    cost_per_request: float     # FLOPs (or MACs) per request


@dataclass
class GateStats:
    seen: int = 0
    escalated: int = 0

    @property
    def escalation_rate(self) -> float:
        return self.escalated / max(self.seen, 1)


@dataclass
class ServerStats:
    requests: int = 0
    cost: float = 0.0
    gates: List[GateStats] = field(default_factory=list)

    @property
    def cost_per_request(self) -> float:
        return self.cost / max(self.requests, 1)


def delta_for_escalation_rate(confs, target_rate: float) -> float:
    """δ such that ~target_rate of calibration confidences fall at/below
    it (the deployment knob: an escalation *budget* rather than a fixed
    threshold)."""
    confs = np.asarray(confs, np.float64)
    if len(confs) == 0:
        return 0.5
    return float(np.quantile(confs, np.clip(target_rate, 0.0, 1.0)))


class CascadeServer:
    """M-member cascade with packed escalation."""

    def __init__(self, members: Sequence[ServingMember],
                 deltas: Sequence[float]):
        assert len(deltas) == len(members) - 1, "one gate per non-final member"
        self.members = list(members)
        self.deltas = [float(d) for d in deltas]
        self.stats = ServerStats(gates=[GateStats()
                                        for _ in range(len(members) - 1)])

    def serve(self, prompts) -> Tuple[np.ndarray, np.ndarray]:
        """prompts [B, P] -> (outputs [B, G], member_index [B])."""
        prompts = np.asarray(prompts)
        B = prompts.shape[0]
        if B == 0:
            # no member is invoked, so the output length is unknowable:
            # return an empty [0, 0] outputs/handled_by pair
            return np.zeros((0, 0), np.int32), np.zeros(0, np.int32)
        self.stats.requests += B

        active_idx = np.arange(B)
        outputs: Optional[np.ndarray] = None
        handled_by = np.zeros(B, np.int32)

        for m, member in enumerate(self.members):
            sub_prompts = prompts[active_idx]
            self.stats.cost += member.cost_per_request * len(active_idx)
            out, conf = member.generate(sub_prompts)
            out = np.asarray(out)
            conf = np.asarray(conf)
            if outputs is None:
                outputs = np.zeros((B,) + out.shape[1:], out.dtype)
            outputs[active_idx] = out
            handled_by[active_idx] = m

            if m == len(self.members) - 1:
                break
            gate = self.stats.gates[m]
            gate.seen += len(active_idx)
            esc_mask = conf <= self.deltas[m]
            gate.escalated += int(esc_mask.sum())
            active_idx = active_idx[esc_mask]          # packed sub-batch
            if len(active_idx) == 0:
                break

        return outputs, handled_by

    def summary(self) -> dict:
        s = self.stats
        return {
            "requests": s.requests,
            "cost_per_request": s.cost_per_request,
            "always_fast_cost": self.members[0].cost_per_request,
            "always_expensive_cost": sum(m.cost_per_request
                                         for m in self.members),
            "escalation_rates": [g.escalation_rate for g in s.gates],
        }
