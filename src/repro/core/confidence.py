"""Confidence scores.

The paper uses the maximum softmax probability of the fast model as the
confidence score ``conf`` (§3, §4).  We provide the standard alternatives
as well; all are differentiable in the logits (the indicator terms of the
LtC loss are the non-differentiable parts and are stop-gradiented in
``repro.core.losses``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def max_prob(logits, temperature: float = 1.0):
    """Maximum softmax probability — the paper's conf (Eq 3)."""
    return jnp.max(jax.nn.softmax(logits / temperature, axis=-1), axis=-1)


def entropy(logits, temperature: float = 1.0):
    """Shannon entropy of the predictive distribution (nats)."""
    logp = jax.nn.log_softmax(logits / temperature, axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def entropy_confidence(logits, temperature: float = 1.0):
    """1 - H/log(K): entropy mapped to a [0,1] confidence."""
    k = logits.shape[-1]
    return 1.0 - entropy(logits, temperature) / jnp.log(k)


def margin(logits, temperature: float = 1.0):
    """Top-1 minus top-2 softmax probability."""
    p = jax.nn.softmax(logits / temperature, axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    return top2[..., 0] - top2[..., 1]


SCORES = {
    "max_prob": max_prob,
    "entropy": entropy_confidence,
    "margin": margin,
}


def score(logits, kind: str = "max_prob", temperature: float = 1.0):
    return SCORES[kind](logits, temperature)


def sequence_confidence(token_conf, mask=None, reduce: str = "mean"):
    """Aggregate per-token confidences to a per-sequence score.

    Used by the LLM cascade server: a sequence is escalated when its
    aggregate confidence falls below δ.  reduce: 'mean' | 'min' | 'prod'.
    """
    if mask is None:
        mask = jnp.ones_like(token_conf)
    mask = mask.astype(token_conf.dtype)
    if reduce == "mean":
        return jnp.sum(token_conf * mask, -1) / jnp.maximum(jnp.sum(mask, -1), 1)
    if reduce == "min":
        big = jnp.where(mask > 0, token_conf, jnp.inf)
        return jnp.min(big, axis=-1)
    if reduce == "prod":
        logc = jnp.where(mask > 0, jnp.log(jnp.clip(token_conf, 1e-9, 1.0)), 0.0)
        return jnp.exp(jnp.sum(logc, axis=-1))
    raise ValueError(reduce)
