"""δ selection on the validation split (paper §5: 'We used a set of
validation images to search for the δ with the highest cascade accuracy').

Two policies are provided:

  * :func:`best_accuracy_delta` — the paper's: δ* = argmax Acc^casc(δ)
    (ties broken toward lower cost).
  * :func:`min_cost_delta` — the §3 optimization problem: minimize cost
    subject to Acc^casc ≥ (1-ε)·Acc_target.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cascade import evaluate_cascade


def _sweep(conf, fast_correct, exp_correct, costs, num: int = 201):
    deltas = jnp.linspace(0.0, 1.0, num)
    out = evaluate_cascade(conf[None, :],
                           jnp.stack([fast_correct, exp_correct]),
                           jnp.asarray(costs, jnp.float32),
                           deltas[:, None])
    return deltas, out


def best_accuracy_delta(conf, fast_correct, exp_correct, costs, num=201):
    """Paper policy.  Returns (delta, acc, cost)."""
    deltas, out = _sweep(conf, fast_correct, exp_correct, costs, num)
    acc, cost = out["acc"], out["cost"]
    # lexicographic: max acc, then min cost
    score = acc - 1e-9 * cost / jnp.maximum(jnp.max(cost), 1e-9)
    i = int(jnp.argmax(score))
    return float(deltas[i]), float(acc[i]), float(cost[i])


def min_cost_delta(conf, fast_correct, exp_correct, costs, acc_target,
                   eps: float = 0.0, num=201):
    """§3 objective: min N^exp s.t. Acc^casc >= (1-eps)·acc_target.
    Falls back to best-accuracy δ if the constraint is infeasible."""
    deltas, out = _sweep(conf, fast_correct, exp_correct, costs, num)
    acc, cost = out["acc"], out["cost"]
    ok = acc >= (1.0 - eps) * acc_target
    feasible = bool(jnp.any(ok))
    if not feasible:
        i = int(jnp.argmax(acc))
    else:
        big = jnp.where(ok, cost, jnp.inf)
        i = int(jnp.argmin(big))
    return float(deltas[i]), float(acc[i]), float(cost[i]), feasible
