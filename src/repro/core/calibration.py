"""Confidence-calibration baselines the paper compares against (§5).

  * Baseline           — raw max softmax probability (no calibration).
  * TemperatureScaling — Guo et al. 2017: one scalar T fit by NLL on the
    validation split.
  * ConfNet / IDK      — auxiliary confidence heads (one hidden layer on
    the fast model's features).  ConfNet is trained to predict the fast
    model's correctness (BCE); IDK optimizes the oracle-expensive cascade
    objective.  Their losses live in repro.core.losses; here is the head
    itself + the post-hoc fitting loops.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import losses


# --------------------------------------------------------------------------
# Temperature scaling
# --------------------------------------------------------------------------


def fit_temperature(logits, labels, *, steps: int = 200, lr: float = 0.01):
    """Fit T minimizing NLL(logits/T, labels) by gradient descent on log T."""

    def nll(log_t):
        return losses.cross_entropy(logits / jnp.exp(log_t), labels)

    g = jax.jit(jax.value_and_grad(nll))
    log_t = jnp.zeros(())
    for _ in range(steps):
        _, grad = g(log_t)
        log_t = log_t - lr * grad
    return float(jnp.exp(log_t))


# --------------------------------------------------------------------------
# Auxiliary confidence head (ConfNet / IDK)
# --------------------------------------------------------------------------


class ConfHead(NamedTuple):
    w1: jnp.ndarray
    b1: jnp.ndarray
    w2: jnp.ndarray
    b2: jnp.ndarray


def init_conf_head(key, feat_dim: int, hidden: int = 64) -> ConfHead:
    k1, k2 = jax.random.split(key)
    return ConfHead(
        w1=jax.random.normal(k1, (feat_dim, hidden)) / jnp.sqrt(feat_dim),
        b1=jnp.zeros((hidden,)),
        w2=jax.random.normal(k2, (hidden, 1)) / jnp.sqrt(hidden),
        b2=jnp.zeros((1,)),
    )


def conf_head_apply(head: ConfHead, feats) -> jnp.ndarray:
    h = jax.nn.relu(feats @ head.w1 + head.b1)
    return jax.nn.sigmoid((h @ head.w2 + head.b2)[..., 0])


def fit_conf_head(key, feats, fast_logits, labels, *, kind: str = "confnet",
                  cost_c: float = 0.5, steps: int = 500, lr: float = 1e-2,
                  hidden: int = 64):
    """Post-hoc training of the auxiliary head on held-out features.

    kind: 'confnet' (BCE to self-correctness) | 'idk' (oracle cascade
    objective)."""
    head = init_conf_head(key, feats.shape[-1], hidden)
    # correctness of the (frozen) fast model is a constant of the fit
    target = losses.correct(fast_logits, labels)
    fast_wrong = 1.0 - target

    def loss_fn(h, feats, target, fast_wrong):
        conf = conf_head_apply(h, feats)
        p = jnp.clip(conf, 1e-6, 1 - 1e-6)
        if kind == "confnet":
            return -jnp.mean(target * jnp.log(p)
                             + (1 - target) * jnp.log(1 - p))
        return jnp.mean(conf * fast_wrong + (1.0 - conf) * cost_c)

    # data enters as jit args (not closure constants: XLA would
    # constant-fold the whole-split argmax on every compile)
    g = jax.jit(jax.value_and_grad(loss_fn))
    # plain Adam
    m = jax.tree.map(jnp.zeros_like, head)
    v = jax.tree.map(jnp.zeros_like, head)
    for t in range(1, steps + 1):
        _, grad = g(head, feats, target, fast_wrong)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, grad)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, grad)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        head = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8),
                            head, mh, vh)
    return head


# --------------------------------------------------------------------------
# Metrics
# --------------------------------------------------------------------------


def ece(confs, corrects, bins: int = 15) -> float:
    """Expected Calibration Error (Guo et al. 2017)."""
    confs = jnp.asarray(confs)
    corrects = jnp.asarray(corrects, jnp.float32)
    edges = jnp.linspace(0.0, 1.0, bins + 1)
    total = confs.shape[0]
    err = 0.0
    for i in range(bins):
        in_bin = (confs > edges[i]) & (confs <= edges[i + 1])
        n = jnp.sum(in_bin)
        avg_conf = jnp.sum(jnp.where(in_bin, confs, 0)) / jnp.maximum(n, 1)
        avg_acc = jnp.sum(jnp.where(in_bin, corrects, 0)) / jnp.maximum(n, 1)
        err += jnp.where(n > 0, n / total * jnp.abs(avg_conf - avg_acc), 0.0)
    return float(err)
