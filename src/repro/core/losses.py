"""Loss functions: the paper's contribution (Eqs 3–6) plus the substrate.

``cascade_loss`` is Eq 3 verbatim:

    L_casc = mean( conf · 1[y != argmax fast]
                 + (1-conf) · (1[y != argmax exp] + C) )

``conf`` is the max softmax probability of the fast model (differentiable);
the correctness indicators are constants w.r.t. the fast model's params
(the expensive model is frozen; argmax is non-differentiable anyway) and
are stop-gradiented explicitly for clarity.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import confidence as C


def cross_entropy(logits, labels, mask=None, label_smoothing: float = 0.0):
    """Mean softmax cross-entropy.  labels: int [...]; logits [..., K].

    Written as ``logsumexp - <one_hot, logits>`` rather than
    log_softmax + gather: elementwise ops + reductions partition cleanly
    under GSPMD when the vocab dim is sharded (a take_along_axis gather on
    a sharded dim forces an all-gather of the full logits — measured
    >500 GB/chip on the kimi-k2 train dry-run)."""
    k = logits.shape[-1]
    x = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(x, axis=-1)
    oh = jax.nn.one_hot(labels, k, dtype=jnp.float32)
    if oh.ndim >= 2:
        from repro.models.sharding import shard_hint
        oh = shard_hint(oh, "batch", *([None] * (oh.ndim - 2)), "model")
    label_logit = jnp.einsum("...v,...v->...", x, oh)
    nll = lse - label_logit
    if label_smoothing:
        uniform = lse - jnp.mean(x, axis=-1)
        nll = (1 - label_smoothing) * nll + label_smoothing * uniform
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


def chunked_lm_loss(hidden, proj, labels, chunk: int = 512, mask=None):
    """Next-token CE computed per sequence chunk without ever
    materializing the full [B,S,V] logits (§Perf: the logits transient is
    the residual memory hog on 200k+-vocab archs).

    hidden [B,S,D] (final-norm output), proj [D,V] (lm head / embed.T),
    labels [B,S].  The scan over S-chunks keeps one [B,chunk,V] logits
    block live at a time; backward recomputes each block (checkpointed).
    """
    B, S, D = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S += pad
    n = S // chunk
    hs = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    ms = mask.astype(jnp.float32).reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h, l, m = xs
        logits = h @ proj
        x = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(x, axis=-1)
        oh = jax.nn.one_hot(l, x.shape[-1], dtype=jnp.float32)
        from repro.models.sharding import shard_hint
        oh = shard_hint(oh, "batch", None, "model")
        nll = lse - jnp.einsum("...v,...v->...", x, oh)
        tot, cnt = carry
        return (tot + jnp.sum(nll * m), cnt + jnp.sum(m)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def correct(logits, labels):
    """1[argmax(logits) == label], float32, stop-gradiented."""
    pred = jnp.argmax(logits, axis=-1)
    return jax.lax.stop_gradient((pred == labels).astype(jnp.float32))


def cascade_loss(fast_logits, exp_logits, labels, cost_c: float = 0.5,
                 mask=None, conf_kind: str = "max_prob"):
    """Eq 3 of the paper.  Shapes: logits [..., K], labels [...]."""
    conf = C.score(fast_logits, conf_kind)
    fast_wrong = 1.0 - correct(fast_logits, labels)
    exp_wrong = 1.0 - correct(exp_logits, labels)
    per = conf * fast_wrong + (1.0 - conf) * (exp_wrong + cost_c)
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(per * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(per)


def ltc_loss(fast_logits, exp_logits, labels, *, w: float = 1.0,
             cost_c: float = 0.5, mask=None, label_smoothing: float = 0.0):
    """Eq 4: L = L_org + w·L_casc.  Returns (loss, metrics-dict)."""
    l_org = cross_entropy(fast_logits, labels, mask, label_smoothing)
    l_casc = cascade_loss(fast_logits, exp_logits, labels, cost_c, mask)
    return l_org + w * l_casc, {"l_org": l_org, "l_casc": l_casc}


def ltc_chain_loss(logits_chain: Sequence, labels, *, w: float = 1.0,
                   cost_c: float = 0.5, mask=None):
    """Eq 6 (model splitting): joint loss over M exits trained together.

    logits_chain[m] is the m-th exit's logits, sorted fast -> expensive
    (the final element is the last exit / full model).

        L = Σ_{m<M} { L_org^(m) + w·L_casc^(m,m+1) } + L_org^(M)
    """
    total = cross_entropy(logits_chain[-1], labels, mask)
    metrics = {}
    for m in range(len(logits_chain) - 1):
        l_org = cross_entropy(logits_chain[m], labels, mask)
        l_casc = cascade_loss(logits_chain[m],
                              jax.lax.stop_gradient(logits_chain[m + 1]),
                              labels, cost_c, mask)
        total = total + l_org + w * l_casc
        metrics[f"l_org_{m}"] = l_org
        metrics[f"l_casc_{m}"] = l_casc
    return total, metrics


def moe_aux_loss(aux, lb_coef: float = 0.01, z_coef: float = 1e-3):
    """Router load-balance + z-loss accumulated by the MoE blocks."""
    return lb_coef * aux.get("lb_loss", 0.0) + z_coef * aux.get("z_loss", 0.0)


# ---- auxiliary-head losses for the comparison baselines -------------------


def confnet_loss(conf_pred, fast_logits, labels, mask=None):
    """ConfNet (Wan et al. 2018): BCE of an auxiliary confidence head
    against the fast model's own correctness — calibration to *self*."""
    target = correct(fast_logits, labels)
    p = jnp.clip(conf_pred, 1e-6, 1 - 1e-6)
    per = -(target * jnp.log(p) + (1 - target) * jnp.log(1 - p))
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(per * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(per)


def idk_loss(conf_pred, fast_logits, labels, cost_c: float = 0.5, mask=None):
    """IDK Cascades (Wang et al. 2018): auxiliary head optimizing the
    cascade objective under an *oracle* expensive model (no exp-wrong term —
    the difference from LtC the paper's discussion highlights)."""
    fast_wrong = 1.0 - correct(fast_logits, labels)
    per = conf_pred * fast_wrong + (1.0 - conf_pred) * cost_c
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(per * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(per)
