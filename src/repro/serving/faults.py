"""Deterministic fault injection for the serving runtime.

A :class:`FaultPlan` is a seeded, reproducible schedule of adverse
conditions threaded through :class:`repro.serving.CascadeEngine` behind
zero-cost-when-None hooks (the same pattern as the Tracer: every call
site is guarded, a ``faults=None`` engine builds no objects and takes no
branches beyond the None check).  Four fault families:

  * **Pool shrinkage** — ``Shrink(tick, tier, blocks, restore_tick)``
    withholds free KV blocks from a tier's arena mid-run
    (:meth:`repro.serving.slots.TierSlotPool.shrink`), forcing the
    over-subscription machinery (stalls, or preemption when a policy is
    set) to absorb a capacity loss.  The shrink caps keep the run
    deadlock-free by construction; ``restore_tick`` returns the blocks.
  * **Escalation storms** — ``Storm(start, end, gate)`` forces every
    gate decision at ``gate`` to escalate during ticks
    ``[start, end)``: the miscalibrated-confidence overload the paper's
    calibration work exists to prevent, driven through
    ``CascadeScheduler.gate_decision(force=True)`` so stats and
    calibration telemetry see it like real traffic.
  * **Transient launch failures** — raise :class:`TransientError` from
    inside the engine's retry wrapper, either probabilistically
    (``launch_fail_prob``, seeded and keyed by (tick, tier, kind) so
    draws are order-independent) or at targeted ticks
    (``fail_launches={(tick, tier): attempts}``).  Failures spanning
    fewer attempts than the engine's retry budget recover invisibly;
    more, and the engine sacrifices a single request (FAILED) rather
    than the run.
  * **Slow ticks** — seeded probabilistic ``time.sleep`` at tick start:
    host-side scheduling jitter for wall-clock runs.

Determinism: every probabilistic draw is a pure function of
``(seed, tick, ...)`` via ``np.random.default_rng`` keyed sequences —
no shared RNG state, so the same plan over the same workload injects
the same faults regardless of call order.

CLI spec format (``serve_async --inject-faults SPEC``): comma-separated
``key=value`` entries, repeatable where it makes sense::

    seed=N                            RNG seed (default 0)
    shrink=TICK:TIER:BLOCKS[:RESTORE] withhold BLOCKS from TIER's arena
                                      at TICK (restore at tick RESTORE)
    storm=START-END:GATE              force-escalate GATE during
                                      ticks [START, END)
    launch=PROB[:ATTEMPTS]            each (tick, tier, kind) launch
                                      fails w.p. PROB for ATTEMPTS
                                      consecutive attempts (default 1)
    launchat=TICK:TIER[:ATTEMPTS]     deterministic launch failure
    slow=PROB:SECONDS                 sleep SECONDS before a tick w.p.
                                      PROB

Example: ``--inject-faults "seed=7,shrink=5:0:8:40,storm=10-14:0,launch=0.05"``
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


class TransientError(RuntimeError):
    """A fault-injected transient launch/transfer failure: the kind of
    error the engine's bounded retry-with-backoff path absorbs."""


@dataclass(frozen=True)
class Shrink:
    """Withhold `blocks` free KV blocks from `tier`'s arena at `tick`
    (restored at `restore_tick`; None = never)."""
    tick: int
    tier: int
    blocks: int
    restore_tick: Optional[int] = None


@dataclass(frozen=True)
class Storm:
    """Force every decision at `gate` to escalate during ticks
    ``[start, end)`` — a simulated gate-miscalibration overload."""
    start: int
    end: int
    gate: int = 0


# stable small codes for launch kinds, so probabilistic draws can be
# keyed per kind without hashing strings (unknown kinds share one code)
_KIND_CODES = {"run_mixed": 1, "run_chunk": 2, "run_step": 3,
               "run_prefill": 4, "device_get": 5}


@dataclass
class FaultPlan:
    """A seeded, deterministic schedule of injected faults (see module
    docstring).  Construct directly for tests, or :meth:`parse` the CLI
    spec string."""
    seed: int = 0
    shrinks: Tuple[Shrink, ...] = ()
    storms: Tuple[Storm, ...] = ()
    #: targeted launch failures: (tick, tier) -> consecutive failing
    #: attempts (attempts > the engine's retry budget exhaust it)
    fail_launches: Dict[Tuple[int, int], int] = field(default_factory=dict)
    launch_fail_prob: float = 0.0
    launch_fail_attempts: int = 1
    slow_tick_prob: float = 0.0
    slow_tick_seconds: float = 0.0
    #: applied-event log [(tick, kind, detail), ...] — what actually
    #: fired, for tests and the CLI summary
    log: List[tuple] = field(default_factory=list)

    # -- deterministic draws -------------------------------------------------

    def _draw(self, *key: int) -> float:
        """A uniform [0,1) draw that is a pure function of (seed, *key):
        order-independent, replay-stable."""
        return float(np.random.default_rng(
            [self.seed] + [int(k) for k in key]).random())

    # -- engine hooks (each guarded by `if faults is not None` there) --------

    def begin_tick(self, tick: int, engine) -> None:
        """Tick-start faults: apply scheduled shrinks/restores to the
        engine's tier pools and (seeded) sleep for a slow tick."""
        for ev in self.shrinks:
            pool = engine.runtimes[ev.tier].pool
            if not hasattr(pool, "shrink"):
                continue            # dense arenas have no block pool
            if ev.tick == tick:
                took = pool.shrink(ev.blocks)
                self.log.append((tick, "shrink",
                                 {"tier": ev.tier, "requested": ev.blocks,
                                  "withheld": took}))
            if ev.restore_tick == tick:
                back = pool.unshrink()
                self.log.append((tick, "restore",
                                 {"tier": ev.tier, "restored": back}))
        if self.slow_tick_prob > 0.0 and \
                self._draw(tick, 7001) < self.slow_tick_prob:
            self.log.append((tick, "slow",
                             {"seconds": self.slow_tick_seconds}))
            time.sleep(self.slow_tick_seconds)

    def pre_launch(self, tick: int, tier: int, kind: str,
                   attempt: int) -> None:
        """Called inside the engine's retry wrapper before each launch
        attempt; raises :class:`TransientError` when the plan says this
        (tick, tier, kind) fails at this attempt index."""
        times = self.fail_launches.get((tick, tier))
        if times is not None and attempt < times:
            self.log.append((tick, "launch_fault",
                             {"tier": tier, "kind": kind,
                              "attempt": attempt, "targeted": True}))
            raise TransientError(
                f"injected launch failure: tick {tick} tier {tier} "
                f"{kind} attempt {attempt}")
        if self.launch_fail_prob > 0.0 and \
                attempt < self.launch_fail_attempts and \
                self._draw(tick, tier, _KIND_CODES.get(kind, 0)) \
                < self.launch_fail_prob:
            self.log.append((tick, "launch_fault",
                             {"tier": tier, "kind": kind,
                              "attempt": attempt, "targeted": False}))
            raise TransientError(
                f"injected launch failure: tick {tick} tier {tier} "
                f"{kind} attempt {attempt}")

    def force_escalation(self, tick: int, gate: int) -> Optional[bool]:
        """True when a storm covers (tick, gate); None = no override."""
        for st in self.storms:
            if st.gate == gate and st.start <= tick < st.end:
                return True
        return None

    # -- CLI spec ------------------------------------------------------------

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Build a plan from the ``--inject-faults`` spec string (see
        module docstring for the grammar)."""
        kw: dict = {"shrinks": [], "storms": [], "fail_launches": {}}
        for entry in spec.split(","):
            entry = entry.strip()
            if not entry:
                continue
            key, sep, val = entry.partition("=")
            if not sep:
                raise ValueError(f"fault spec entry {entry!r}: "
                                 "expected key=value")
            if key == "seed":
                kw["seed"] = int(val)
            elif key == "shrink":
                parts = [int(x) for x in val.split(":")]
                if len(parts) not in (3, 4):
                    raise ValueError(
                        f"shrink={val!r}: want TICK:TIER:BLOCKS[:RESTORE]")
                kw["shrinks"].append(Shrink(*parts))
            elif key == "storm":
                rng, _, gate = val.partition(":")
                start, sep2, end = rng.partition("-")
                if not sep2:
                    raise ValueError(
                        f"storm={val!r}: want START-END[:GATE]")
                kw["storms"].append(Storm(int(start), int(end),
                                          int(gate or 0)))
            elif key == "launch":
                prob, _, attempts = val.partition(":")
                kw["launch_fail_prob"] = float(prob)
                if attempts:
                    kw["launch_fail_attempts"] = int(attempts)
            elif key == "launchat":
                parts = [int(x) for x in val.split(":")]
                if len(parts) not in (2, 3):
                    raise ValueError(
                        f"launchat={val!r}: want TICK:TIER[:ATTEMPTS]")
                tick, tier = parts[0], parts[1]
                kw["fail_launches"][(tick, tier)] = (
                    parts[2] if len(parts) == 3 else 1)
            elif key == "slow":
                prob, sep2, secs = val.partition(":")
                if not sep2:
                    raise ValueError(f"slow={val!r}: want PROB:SECONDS")
                kw["slow_tick_prob"] = float(prob)
                kw["slow_tick_seconds"] = float(secs)
            else:
                raise ValueError(f"unknown fault spec key {key!r}")
        kw["shrinks"] = tuple(kw["shrinks"])
        kw["storms"] = tuple(kw["storms"])
        return cls(**kw)

    def describe(self) -> dict:
        """A json-able summary of the plan (recorded into run summaries)."""
        return {
            "seed": self.seed,
            "shrinks": [dataclasses.asdict(s) for s in self.shrinks],
            "storms": [dataclasses.asdict(s) for s in self.storms],
            "fail_launches": {f"{t}:{m}": n for (t, m), n
                              in self.fail_launches.items()},
            "launch_fail_prob": self.launch_fail_prob,
            "launch_fail_attempts": self.launch_fail_attempts,
            "slow_tick_prob": self.slow_tick_prob,
            "slow_tick_seconds": self.slow_tick_seconds,
        }
