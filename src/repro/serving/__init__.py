"""Asynchronous cascade serving runtime.

Request-level scheduling for the paper's cascade (Fig 1, Eqs 1/2/7):
continuous batching over fixed slot pools, per-request confidence gating,
and escalation queues feeding the expensive members as packed sub-batches.

  * :mod:`repro.serving.request`   — request lifecycle state machine
  * :mod:`repro.serving.slots`     — block-paged KV arenas (free-list of
    fixed-size blocks + per-request page tables)
  * :mod:`repro.serving.scheduler` — continuous batching + escalation queues
  * :mod:`repro.serving.metrics`   — latency/throughput/Eq 7 accounting
  * :mod:`repro.serving.observability` — request/tick tracer (Perfetto
    export), streaming gate-calibration telemetry (ECE + reliability),
    jax-profiler hooks
  * :mod:`repro.serving.faults`    — deterministic fault injection
    (pool shrinkage, escalation storms, transient launch failures, slow
    ticks) behind zero-cost-when-None engine hooks
  * :mod:`repro.serving.engine`    — CascadeEngine tying tiers together
"""
from repro.serving.engine import CascadeEngine, TierSpec  # noqa: F401
from repro.serving.faults import FaultPlan, TransientError  # noqa: F401
from repro.serving.metrics import ServingMetrics  # noqa: F401
from repro.serving.observability import (GateCalibration,  # noqa: F401
                                         ReliabilityBins, Tracer)
from repro.serving.request import Request, RequestState  # noqa: F401
from repro.serving.scheduler import (CascadeScheduler, GateSpec)  # noqa: F401
from repro.serving.slots import (BlockAllocator, DenseTierSlotPool,  # noqa: F401
                                 SlotAllocator, TierSlotPool)

__all__ = [
    "CascadeEngine", "TierSpec", "ServingMetrics", "Request", "RequestState",
    "CascadeScheduler", "GateSpec", "SlotAllocator", "BlockAllocator",
    "TierSlotPool", "DenseTierSlotPool", "Tracer", "GateCalibration",
    "ReliabilityBins", "FaultPlan", "TransientError",
]
