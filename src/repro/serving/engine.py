"""CascadeEngine: request-level cascade inference.

One engine step (tick) per tier:

  1. **admit** — pop queued/escalated requests into free KV slots
     (continuous batching: admission happens while other slots are mid
     decode).  Admitted prompts are packed densely, prefilled in one
     batch, and their caches scattered into the tier's slot arena; the
     first token (argmax of the prefill logits) is emitted immediately.
  2. **decode** — one fused decode step over the whole slot pool (fixed
     shape => a single compiled program per tier), attending through the
     block-paged KV arena with the Pallas paged flash-decode kernel
     (:mod:`repro.kernels.paged_attention`; page tables grow lazily as
     rows cross block boundaries).  Per-token confidence comes from the
     Pallas :func:`repro.kernels.ops.confidence_gate` (max-softmax-prob,
     the paper's conf) or a jnp fallback.
  3. **gate** — requests that hit ``gen_len`` aggregate their token
     confidences; at non-final tiers the scheduler's gate (fixed δ or
     escalation budget) decides DONE vs ESCALATED.  Escalated requests
     join the next tier's queue and are re-decoded there from scratch.

The clock is injectable: ``WallClock`` for real Poisson traffic,
``VirtualClock`` for deterministic tests (one tick per step).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import confidence as conf_lib
from repro.kernels import ops as kernel_ops
from repro.models import cache as cache_lib
from repro.models import transformer
from repro.serving.metrics import ServingMetrics, TierCost
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import CascadeScheduler, GateSpec
from repro.serving.slots import DenseTierSlotPool, TierSlotPool


@dataclass
class TierSpec:
    name: str
    cfg: ModelConfig
    params: object

    def flops_per_request(self, gen_len: int) -> float:
        """Eq 7 cost: FLOPs/token = 2 * active params (as in launch.serve)."""
        return 2.0 * self.cfg.active_param_count() * gen_len


class WallClock:
    def __init__(self):
        self._t0 = time.perf_counter()

    def reset(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t: float) -> None:
        time.sleep(min(max(t - self.now(), 0.0), 0.05))

    def step_done(self) -> None:
        pass


class VirtualClock:
    """Deterministic clock: one tick per engine step."""

    def __init__(self, dt: float = 1.0):
        self.t = 0.0
        self.dt = dt

    def reset(self) -> None:
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def wait_until(self, t: float) -> None:
        self.t = max(self.t, t)

    def step_done(self) -> None:
        self.t += self.dt


class _TierRuntime:
    """Per-tier compiled functions + host-side slot state."""

    def __init__(self, spec: TierSpec, capacity: int, prompt_len: int,
                 max_seq: int, use_gate_kernel: bool, *,
                 use_paged_kv: bool = True, block_size: int = 16,
                 kv_blocks: Optional[int] = None):
        self.spec = spec
        self.capacity = capacity
        self.prompt_len = prompt_len
        self.paged = use_paged_kv
        if use_paged_kv:
            self.pool = TierSlotPool(spec.cfg, capacity, max_seq,
                                     block_size=block_size,
                                     num_blocks=kv_blocks)
        else:
            self.pool = DenseTierSlotPool(spec.cfg, capacity, max_seq)
        self.slot_req: List[Optional[Request]] = [None] * capacity
        self.tok = np.zeros(capacity, np.int32)
        self.pos = np.zeros(capacity, np.int32)
        cfg = spec.cfg

        def pick(logits2d):
            if use_gate_kernel:
                gate = kernel_ops.confidence_gate(logits2d)
                return gate["argmax"].astype(jnp.int32), gate["conf"]
            return (jnp.argmax(logits2d, -1).astype(jnp.int32),
                    conf_lib.max_prob(logits2d))

        def prefill_fn(params, prompts):
            batch = {"tokens": prompts}
            if cfg.frontend:
                batch["frontend_embeds"] = jnp.zeros(
                    (prompts.shape[0], cfg.frontend_len, cfg.frontend_dim),
                    jnp.float32)
            logits, part_cache, _ = transformer.forward(
                params, cfg, batch, mode="prefill")
            tok, conf = pick(logits[:, -1])
            return part_cache, tok, conf

        def step_fn(params, tok, cache, pos, page_table):
            pages = {"page_table": page_table} if use_paged_kv else None
            logits, new_cache = transformer.decode_step(
                params, cfg, tok, cache, pos, pages=pages)
            nxt, conf = pick(logits[:, 0])
            return nxt, conf, new_cache

        self.prefill_fn = jax.jit(prefill_fn)
        # Donate the cache so XLA updates the slot arena in place instead
        # of copying it every token (2x peak cache memory otherwise).  CPU
        # ignores donation and warns, so only donate on accelerators.
        donate = (2,) if jax.default_backend() != "cpu" else ()
        self.step_fn = jax.jit(step_fn, donate_argnums=donate)

    def page_table_device(self):
        if self.paged:
            return jnp.asarray(self.pool.page_table)
        # dense pools take a dummy (the traced fn ignores it)
        return jnp.zeros((self.capacity, 1), jnp.int32)

    def occupied(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is not None]

    def decoding(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req)
                if r is not None and r.state is RequestState.DECODE
                and not r.decode_finished]


class CascadeEngine:
    """M-tier cascade with continuous batching and per-request gating."""

    def __init__(self, tiers: Sequence[TierSpec], *,
                 slots: int | Sequence[int] = 8,
                 prompt_len: int = 32, gen_len: int = 16,
                 deltas: Optional[Sequence[float]] = None,
                 escalation_budget: Optional[float] = None,
                 conf_reduce: str = "mean",
                 use_gate_kernel: bool = True,
                 use_paged_kv: bool = True,
                 kv_block_size: int = 16,
                 kv_blocks: Optional[int | Sequence[Optional[int]]] = None,
                 clock=None):
        """``use_paged_kv`` selects the block-paged KV arena + Pallas
        paged flash-decode kernel (interpret mode off-TPU); False keeps
        the PR 1 dense one-page-per-request arena (the reference path).
        ``kv_blocks`` sizes each tier's arena in KV *blocks* of
        ``kv_block_size`` tokens — None fully provisions
        (``slots * ceil(max_seq / block_size) + 1``); a smaller count
        over-subscribes the arena: admission is then block-limited and
        rows may stall a tick waiting for a free block (attention-only
        models; recurrent state cannot replay a stalled step)."""
        if not tiers:
            raise ValueError("need at least one tier")
        self.tiers = list(tiers)
        m = len(self.tiers)
        slots_per_tier = ([int(slots)] * m if np.isscalar(slots)
                          else [int(s) for s in slots])
        kv_blocks_per_tier = (
            [kv_blocks] * m if kv_blocks is None or np.isscalar(kv_blocks)
            else [None if b is None else int(b) for b in kv_blocks])
        if len(slots_per_tier) != m or len(kv_blocks_per_tier) != m:
            raise ValueError(
                f"per-tier sequences must match the {m} tiers: got "
                f"{len(slots_per_tier)} slots, "
                f"{len(kv_blocks_per_tier)} kv_blocks entries")
        if deltas is not None:
            gates = [GateSpec(delta=float(d)) for d in deltas]
        elif escalation_budget is not None:
            gates = [GateSpec(budget=float(escalation_budget))
                     for _ in range(m - 1)]
        else:
            gates = [GateSpec(delta=0.5) for _ in range(m - 1)]
        if len(gates) != m - 1:
            raise ValueError("one gate per non-final tier")

        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self.conf_reduce = conf_reduce
        self.scheduler = CascadeScheduler(slots_per_tier, gates)
        self.metrics = ServingMetrics(
            [TierCost(t.name, t.flops_per_request(gen_len))
             for t in self.tiers], slots_per_tier)
        self.clock = clock if clock is not None else WallClock()
        max_seq = prompt_len + gen_len
        if use_paged_kv:
            ppr = math.ceil(max_seq / kv_block_size)
            for spec, cap, nb in zip(self.tiers, slots_per_tier,
                                     kv_blocks_per_tier):
                if nb is not None and nb < cap * ppr + 1 \
                        and cache_lib.has_recurrent_state(spec.cfg):
                    raise ValueError(
                        f"tier {spec.name}: kv_blocks={nb} over-subscribes "
                        "the arena but the model carries recurrent state "
                        "(mamba/rwkv), which cannot replay a stalled "
                        "decode step — use full provisioning (kv_blocks="
                        "None)")
        self.runtimes = [
            _TierRuntime(spec, cap, prompt_len, max_seq, use_gate_kernel,
                         use_paged_kv=use_paged_kv, block_size=kv_block_size,
                         kv_blocks=nb)
            for spec, cap, nb in zip(self.tiers, slots_per_tier,
                                     kv_blocks_per_tier)]
        self.requests: List[Request] = []
        self._rid = 0

    # -- submission --------------------------------------------------------

    def submit(self, prompt, arrival_time: float = 0.0) -> Request:
        prompt = np.asarray(prompt, np.int32)
        if prompt.shape != (self.prompt_len,):
            raise ValueError(
                f"prompt must be [{self.prompt_len}], got {prompt.shape} "
                "(the packed prefill batches uniform prompt lengths)")
        req = Request(rid=self._rid, prompt=prompt, gen_len=self.gen_len,
                      arrival_time=float(arrival_time))
        self._rid += 1
        self.requests.append(req)
        self.scheduler.submit(req)
        return req

    # -- one engine tick ---------------------------------------------------

    def _admit(self, tier: int, now: float) -> None:
        rt = self.runtimes[tier]
        if rt.paged:
            # block-aware admission: one request at a time, binding its
            # prompt pages, until rows, blocks, or the queue run out
            # (can_admit leaves the oldest row its worst-case remaining
            # demand — the discipline that makes over-subscription
            # deadlock-free; see serving.slots)
            reqs, slot_ids = [], []
            while rt.pool.can_admit(self.prompt_len):
                r, s = self.scheduler.admit(tier, now, limit=1)
                if not r:
                    break
                rt.pool.bind(s[0], self.prompt_len)
                reqs += r
                slot_ids += s
        else:
            reqs, slot_ids = self.scheduler.admit(tier, now)
        if not reqs:
            return
        self.metrics.record_admission(tier, len(reqs))
        prompts = np.zeros((rt.capacity, self.prompt_len), np.int32)
        for i, req in enumerate(reqs):
            prompts[i] = req.prompt
        part_cache, ftok, fconf = rt.prefill_fn(
            rt.spec.params, jnp.asarray(prompts))
        rt.pool.write_prefill(slot_ids, part_cache)
        # one blocking transfer for both outputs (device_get blocks until
        # prefill finished); timestamp tokens with the post-compute clock
        # so TTFT includes prefill, not just queueing (VirtualClock is
        # constant within a step, so ticks are unaffected)
        ftok, fconf = jax.device_get((ftok, fconf))
        t_emit = self.clock.now()
        for i, (req, slot) in enumerate(zip(reqs, slot_ids)):
            req.start_decode()
            req.emit(int(ftok[i]), float(fconf[i]), t_emit)
            rt.slot_req[slot] = req
            rt.tok[slot] = ftok[i]
            rt.pos[slot] = self.prompt_len   # next decode writes here

    def _decode(self, tier: int, now: float) -> int:
        rt = self.runtimes[tier]
        decoding = rt.decoding()
        if not decoding:
            return 0
        if rt.paged:
            # grow page tables lazily as rows cross block boundaries,
            # oldest row first.  A row denied a block *stalls*: its page
            # stays unmapped (writes hit the null block), its output is
            # discarded, and it retries next tick — attention KV replay
            # is idempotent, and over-subscription is rejected at engine
            # construction for models with recurrent state.
            dec = set(decoding)
            active = [s for s in rt.pool.bound_rows()
                      if s in dec and rt.pool.ensure_blocks(
                          s, int(rt.pos[s]))]
            if not active:
                return 0
        else:
            active = decoding
        nxt, conf, rt.pool.cache = rt.step_fn(
            rt.spec.params, jnp.asarray(rt.tok[:, None]),
            rt.pool.cache, jnp.asarray(rt.pos[:, None]),
            rt.page_table_device())
        # single blocking transfer per tick for both outputs (was two
        # sequential np.asarray syncs)
        nxt, conf = jax.device_get((nxt, conf))
        t_emit = self.clock.now()       # post-compute (see _admit)
        for slot in active:
            req = rt.slot_req[slot]
            req.emit(int(nxt[slot]), float(conf[slot]), t_emit)
            rt.tok[slot] = nxt[slot]
            rt.pos[slot] += 1
        return len(active)

    def _finish(self, tier: int, now: float) -> None:
        rt = self.runtimes[tier]
        last = tier == len(self.tiers) - 1
        for slot in rt.occupied():
            req = rt.slot_req[slot]
            if not (req.state is RequestState.DECODE and req.decode_finished):
                continue
            seq_conf = req.gate(self.conf_reduce)
            if not last and self.scheduler.gate_decision(tier, seq_conf):
                req.escalate()
                self.scheduler.push_escalated(req)
            else:
                # post-compute time: the final decode step belongs to this
                # request's latency (`now` was sampled at step start)
                req.complete(self.clock.now())
                self.metrics.record_completion(req)
            rt.slot_req[slot] = None
            rt.tok[slot] = 0
            rt.pos[slot] = 0
            if rt.paged:
                rt.pool.release(slot)
            self.scheduler.release(tier, slot)

    def step(self, now: Optional[float] = None) -> None:
        now = self.clock.now() if now is None else now
        active = []
        for tier in range(len(self.tiers)):
            self._admit(tier, now)
            active.append(self._decode(tier, now))
            self._finish(tier, now)
        # Trailing admission pass: requests escalated this tick enter the
        # next tier's slots immediately (their decode starts next tick),
        # keeping the invariant `free slot => empty queue` at tick ends.
        for tier in range(len(self.tiers)):
            self._admit(tier, now)
        self.metrics.record_step(active, now)
        self.metrics.sync_gate_stats(self.scheduler.gate_stats)

    # -- driver ------------------------------------------------------------

    def _any_occupied(self) -> bool:
        return any(rt.occupied() for rt in self.runtimes)

    def _done(self) -> bool:
        return self.scheduler.pending == 0 and not self._any_occupied()

    def memory_stats(self) -> List[dict]:
        """Per-tier KV arena accounting: block geometry, static arena
        bytes, high-water bytes actually mapped (paged), and what the
        dense one-page-per-request arena would have allocated."""
        return [dict(tier=rt.spec.name, **rt.pool.memory_stats())
                for rt in self.runtimes]

    def reset_clock(self) -> None:
        """Restart the clock at t=0.  Call after compilation / setup and
        before submitting timed requests, so arrival timestamps are
        relative to the start of serving rather than engine construction."""
        self.clock.reset()

    def warmup(self) -> None:
        """Trigger tier compiles before the clock starts: one prefill +
        one decode per tier on dummy data.  The decode's returned cache is
        rebound (step_fn donates its cache input on accelerators); the
        dummy write lands in the reserved null block (paged: empty page
        tables point at block 0) or at position 0 of free rows (dense),
        neither of which the next occupant ever attends.  Ends by
        resetting the clock so compile time never counts against request
        latency."""
        for rt in self.runtimes:
            prompts = jnp.zeros((rt.capacity, self.prompt_len), jnp.int32)
            rt.prefill_fn(rt.spec.params, prompts)
            zeros = jnp.zeros((rt.capacity, 1), jnp.int32)
            _, _, rt.pool.cache = rt.step_fn(rt.spec.params, zeros,
                                             rt.pool.cache, zeros,
                                             rt.page_table_device())
        self.reset_clock()

    def run(self, max_steps: int = 1_000_000) -> dict:
        """Drive to completion; returns ``metrics.summary()``."""
        steps = 0
        while not self._done():
            now = self.clock.now()
            if not self._any_occupied() and not any(
                    self.scheduler.admissible(t, now)
                    for t in range(len(self.tiers))):
                # idle: jump/sleep to the arrival of the queue *head* —
                # admission is FIFO, so the head is what unblocks the queue
                # (min over all arrivals can sit before the head's time and
                # would spin a VirtualClock forever on out-of-order submits)
                nxt = self.scheduler.queues[0][0].arrival_time
                self.clock.wait_until(nxt)
                continue
            self.step(self.clock.now())
            self.clock.step_done()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("engine did not drain (scheduler stuck?)")
        return self.metrics.summary()
