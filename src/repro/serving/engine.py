"""CascadeEngine: request-level cascade inference.

One engine step (tick) per tier:

  1. **admit** — pop queued/escalated requests into free KV slots
     (continuous batching: admission happens while other slots are mid
     decode).  Under chunked prefill (the default on block-paged,
     attention-only tiers) prompts of *any* length up to
     ``max_prompt_len`` are accepted; admission is bounded by a prompt
     **token budget** per tick and by free KV blocks for the first chunk.
  2. **plan** — a :class:`StepPlan` is built on the host: every live row
     is assigned its tick's work — the next fixed-size chunk of its
     prompt (``q_len = chunk`` or the shorter final tail), its single
     decode token (``q_len = 1``), or a stall (``q_len = 0``, block
     exhaustion) — into one flat ``[capacity, width]`` token batch.
  3. **execute** — **unified token-batch execution** (the default on
     block-paged attention-only tiers): the whole plan runs as ONE
     compiled mixed-attention program per tier per tick
     (``transformer.mixed_step`` over
     :mod:`repro.kernels.mixed_attention`), scattering prefill-chunk KV
     and decode-token KV through the page tables in the same program
     and emitting each row's last-position token + confidence through a
     single blocking ``device_get`` (``CascadeEngine.host_syncs``;
     test-asserted).  Per-token confidence comes from the Pallas
     :func:`repro.kernels.ops.confidence_gate` (max-softmax-prob, the
     paper's conf) or a jnp fallback.  A row's first token (argmax at
     its final prompt position) is emitted when its last chunk
     completes; it starts decoding next tick.  The **split** backend
     (``use_unified_step=False``, and always for dense-arena or
     recurrent-state tiers) executes the same plan as the legacy
     chunk_fn + step_fn pair — two launches on mixed ticks, first
     tokens flowing into the same-tick decode via a device-side
     ``where`` — with token streams bit-identical to unified.  The
     fully legacy path (``use_chunked_prefill=False``) packs
     uniform-length prompts densely, prefills in one shot, and scatters
     the caches — kept as the bit-exactness oracle and for
     recurrent-state models.
  4. **gate** — requests that hit ``gen_len`` aggregate their token
     confidences; at non-final tiers the scheduler's gate (fixed δ or
     escalation budget) decides DONE vs ESCALATED.  Escalated requests
     join the next tier's queue and are re-decoded there from scratch.

Admission and the tick's compute share **one token currency** under
unified execution: the per-tick token budget is pre-charged with the
carried load (decode tokens + in-flight prefill chunks) and a new
request bills only its first chunk — see :meth:`CascadeEngine._admit`.

**Sharded serving**: a tier whose :class:`TierSpec` carries a mesh runs
params, KV arena, and per-tick batches sharded across it — request rows
and the KV block pool partition over the mesh's data shards (shard-aware
admission binds a request's row and blocks on one shard), params
replicate or tensor-shard over 'model', and escalated requests are
re-packed on the host and ``device_put`` under the *target* tier's
sharding.  Token streams are bit-identical to the single-device engine
(multi-device parity suite: ``tests/test_sharded_serving.py``).

**Overload and failure** (docs/serving.md "Overload and failure
semantics"): when the KV block pool runs dry a ``preemption_policy``
(``youngest`` / ``fewest-tokens``) evicts a victim row instead of
stalling it — the victim re-queues as ``PREEMPTED`` and replays
prefill+decode from scratch through the idempotent chunk machinery
(greedy decode is deterministic, so the replayed stream is
bit-identical).  ``submit(deadline=)`` plus a per-tick shedding pass
reject queued requests that cannot meet their deadline (``SHED``).
Every launch and ``device_get`` runs under bounded retry-with-backoff;
when retries exhaust the engine fails a single victim request
(``FAILED``), never the run.  A :class:`repro.serving.faults.FaultPlan`
injects all of these conditions deterministically behind
zero-cost-when-None hooks.

The clock is injectable: ``WallClock`` for real Poisson traffic,
``VirtualClock`` for deterministic tests (one tick per step).
"""
from __future__ import annotations

import contextlib
import math
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.core import confidence as conf_lib
from repro.kernels import ops as kernel_ops
from repro.models import cache as cache_lib
from repro.models import params as params_lib
from repro.models import sharding as sharding_lib
from repro.models import transformer
from repro.serving import faults as faults_lib
from repro.serving import observability as obs
from repro.serving.metrics import ServingMetrics, TierCost
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import CascadeScheduler, GateSpec
from repro.serving.slots import DenseTierSlotPool, TierSlotPool


@dataclass
class TierSpec:
    """One cascade member: model + params, and optionally its own mesh.

    ``mesh`` places the tier on a device mesh with ('data', 'model')
    axes (see ``launch/mesh.py::make_tier_mesh``): params are replicated
    across it (or tensor-sharded when ``shard_params`` — MaxText-style
    ``models/params.py::param_specs`` rules), the KV arena shards its
    request rows and block pool over the data axes, and every per-tick
    host input is ``device_put`` with the tier's row sharding.  Tiers
    may sit on disjoint device subsets (the usual production layout —
    the heavy tier gets more chips) or share devices.  ``mesh=None``
    keeps the single-device behaviour, bit-identical to a sharded run.
    """
    name: str
    cfg: ModelConfig
    params: object
    mesh: Optional[jax.sharding.Mesh] = None
    shard_params: bool = False

    def flops_per_request(self, gen_len: int) -> float:
        """Eq 7 cost: FLOPs/token = 2 * active params (as in launch.serve)."""
        return 2.0 * self.cfg.active_param_count() * gen_len

    def data_shards(self) -> int:
        return sharding_lib.data_axis_size(self.mesh)


class WallClock:
    def __init__(self):
        self._t0 = time.perf_counter()

    def reset(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t: float) -> None:
        time.sleep(min(max(t - self.now(), 0.0), 0.05))

    def step_done(self) -> None:
        pass


class VirtualClock:
    """Deterministic clock: one tick per engine step."""

    def __init__(self, dt: float = 1.0):
        self.t = 0.0
        self.dt = dt

    def reset(self) -> None:
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def wait_until(self, t: float) -> None:
        self.t = max(self.t, t)

    def step_done(self) -> None:
        self.t += self.dt


# per-row kinds in a StepPlan (KIND_DRAFT: a retained draft row catching
# up on its target request's emitted tokens and drafting ahead)
KIND_IDLE, KIND_PREFILL, KIND_DECODE, KIND_STALL, KIND_DRAFT = 0, 1, 2, 3, 4


@dataclass
class StepPlan:
    """One tier's tick, planned on the host before anything launches.

    Pure host-side data: per-row kind (idle / prefill chunk / decode
    token / stalled on block exhaustion), the packed token batch, per-slot
    absolute positions, live-query counts, and the data shard owning each
    row.  Built by :meth:`CascadeEngine._build_plan` from scheduler and
    slot-pool state, then executed by one of two backends behind the same
    interface:

    * **ragged flat** (the default on paged attention-only tiers): one
      :meth:`_TierRuntime.run_ragged` launch consumes the flat packing —
      every live row's tokens concatenated into ``flat_tokens [1, W]``
      (W a bucketed power-of-two width), so the tick's compute is
      O(live tokens) end-to-end.
    * **padded unified** (``use_ragged_step=False``): one
      :meth:`_TierRuntime.run_mixed` launch consumes
      ``tokens``/``pos``/``q_len`` verbatim — every live row's work in a
      single compiled program per tick at ``[capacity, width]``.
    * **split** (``use_unified_step=False`` escape hatch; the only option
      for dense-arena and recurrent-state tiers): the legacy
      ``chunk_fn`` + ``step_fn`` pair, two launches on mixed ticks.

    The executors consume ``tokens``/``pos``/``q_len`` (or the flat
    fields) and the three row lists; ``kind`` and ``shard`` are the
    plan's per-row record of the same decisions (introspection: tests
    and debugging read them, the launch does not — a stall is equally
    expressed by exclusion from ``prefill_rows``/``decode_rows``).
    """
    width: int                  # token slots per row (chunk; 1 decode-only)
    kind: np.ndarray            # [capacity] int8 KIND_*
    tokens: np.ndarray          # [capacity, width] int32
    pos: np.ndarray             # [capacity, width] int32 abs positions
    q_len: np.ndarray           # [capacity] int32 live tokens per row
    shard: np.ndarray           # [capacity] int32 data shard of each row
    prefill_rows: List[int]     # live prefill rows (q_len > 0)
    decode_rows: List[int]      # decode rows (unified: stalls excluded)
    finishing: List[int]        # prefill rows whose last chunk completes
    # ragged flat layout (None on padded/split plans): live tokens of all
    # rows packed contiguously in slot order, padded up to the bucket
    flat_width: Optional[int] = None        # bucketed W >= sum(q_len)
    flat_tokens: Optional[np.ndarray] = None    # [1, W] int32
    flat_pos: Optional[np.ndarray] = None       # [1, W] int32 abs pos
    q_start: Optional[np.ndarray] = None        # [capacity] int32 row pos0
    # speculative cascade decoding (speculation_k > 0; empty otherwise):
    # verify rows are decode rows scoring drafted tokens (q_len = 1 + n),
    # draft rows are retained lower-tier rows catching up on their target
    # request's emitted tokens; draft_len[s] > 0 marks rows that draft
    # ahead after catching up (the device scan masks rows past their
    # per-row budget to the null block)
    verify_rows: List[tuple] = field(default_factory=list)  # (slot, n)
    draft_rows: List[int] = field(default_factory=list)
    draft_len: Optional[np.ndarray] = None      # [capacity] int32

    @property
    def live_prefill_tokens(self) -> int:
        return int(self.q_len[self.prefill_rows].sum()) \
            if self.prefill_rows else 0

    @property
    def live_tokens(self) -> int:
        """Real tokens this tick computes (prefill chunks + decode)."""
        return int(self.q_len.sum())


class _TierRuntime:
    """Per-tier compiled functions + host-side slot state.

    With a tier mesh the runtime owns the device placement seam: params
    are ``device_put`` once at construction (replicated or
    tensor-sharded), every per-tick host array goes through
    :meth:`put_rows` (row dim sharded over the data axes — this is also
    the escalation transfer path: an escalated request's prompt chunks
    are packed on the host and placed under the *target* tier's
    sharding), and the jitted functions run inside the tier's mesh
    context so ``shard_hint`` constraints resolve against it.
    """

    def __init__(self, spec: TierSpec, capacity: int, prompt_len: int,
                 max_seq: int, use_gate_kernel: bool, *,
                 use_paged_kv: bool = True, block_size: int = 16,
                 kv_blocks: Optional[int] = None,
                 use_chunked_prefill: bool = False,
                 prefill_chunk: int = 128,
                 use_unified_step: bool = False,
                 use_ragged_step: bool = False,
                 flat_buckets: Optional[Sequence[int]] = None,
                 prefix_cache: bool = False,
                 speculation_k: int = 0,
                 spec_draft: bool = False):
        self.spec = spec
        self.capacity = capacity
        self.prompt_len = prompt_len          # max prompt length (tokens)
        self.paged = use_paged_kv
        self.chunked = use_chunked_prefill
        self.unified = use_unified_step and use_chunked_prefill
        self.ragged = bool(use_ragged_step) and self.unified
        self.chunk = min(prefill_chunk, prompt_len)
        # ragged flat widths: compiled program shapes are drawn from a
        # small fixed bucket set (powers of two up to the worst-case
        # capacity*chunk tick), so a mixed-length run never recompiles
        # mid-run; warmed/launched width sets feed the compile counter
        self.flat_buckets = (self._default_buckets()
                             if flat_buckets is None
                             else self._validate_buckets(flat_buckets))
        self.warmed_widths: set = set()
        self.launched_widths: set = set()
        self.prefix = bool(prefix_cache) and self.paged and self.chunked
        self.mesh = spec.mesh
        self.data_shards = spec.data_shards()
        if capacity % self.data_shards:
            raise ValueError(
                f"tier {spec.name}: {capacity} slots must divide into the "
                f"mesh's {self.data_shards} data shards")
        if use_paged_kv:
            self.pool = TierSlotPool(spec.cfg, capacity, max_seq,
                                     block_size=block_size,
                                     num_blocks=kv_blocks, mesh=spec.mesh,
                                     prefix_chunk=(self.chunk if self.prefix
                                                   else None))
        else:
            self.pool = DenseTierSlotPool(spec.cfg, capacity, max_seq,
                                          mesh=spec.mesh)
        self.params = self._place_params(spec)
        self.slot_req: List[Optional[Request]] = [None] * capacity
        self.tok = np.zeros(capacity, np.int32)
        self.pos = np.zeros(capacity, np.int32)
        self.prefill_pos = np.zeros(capacity, np.int32)   # tokens written
        # speculative cascade decoding: spec_k > 0 swaps the tier's
        # ragged launch for spec_fn (ragged forward + fused accept/reject
        # epilogue + optional draft scan — still ONE program per tick);
        # draft_req maps retained draft rows to their escalated target
        # request (slot_req stays None there, so every slot_req-driven
        # path — planning, victim picking, finish — skips them for free)
        self.spec_k = int(speculation_k)
        self.spec_draft = bool(spec_draft) and self.spec_k > 0
        self.draft_req: List[Optional[Request]] = [None] * capacity
        cfg = spec.cfg

        def pick(logits2d):
            if use_gate_kernel:
                gate = kernel_ops.confidence_gate(logits2d)
                return gate["argmax"].astype(jnp.int32), gate["conf"]
            return (jnp.argmax(logits2d, -1).astype(jnp.int32),
                    conf_lib.max_prob(logits2d))

        def prefill_fn(params, prompts):
            batch = {"tokens": prompts}
            if cfg.frontend:
                batch["frontend_embeds"] = jnp.zeros(
                    (prompts.shape[0], cfg.frontend_len, cfg.frontend_dim),
                    jnp.float32)
            logits, part_cache, _ = transformer.forward(
                params, cfg, batch, mode="prefill")
            tok, conf = pick(logits[:, -1])
            return part_cache, tok, conf

        def step_fn(params, tok, cache, pos, page_table):
            pages = {"page_table": page_table} if use_paged_kv else None
            logits, new_cache = transformer.decode_step(
                params, cfg, tok, cache, pos, pages=pages)
            nxt, conf = pick(logits[:, 0])
            return nxt, conf, new_cache

        def chunk_fn(params, tokens, cache, pos, page_table, q_len):
            logits, new_cache = transformer.prefill_chunk(
                params, cfg, tokens, cache, pos,
                {"page_table": page_table, "q_len": q_len})
            # first generated token = argmax at each row's last live
            # prompt position; host keeps it only for final chunks
            rows = jnp.arange(logits.shape[0])
            last = jnp.maximum(q_len - 1, 0)
            tok, conf = pick(logits[rows, last])
            return tok, conf, new_cache

        def mixed_fn(params, tokens, cache, pos, page_table, q_len):
            # unified token-batch step: every live row's work — prefill
            # chunk or decode token — in ONE compiled program; q_len
            # selects each row's last live position for the gate
            pages = {"page_table": page_table, "q_len": q_len}
            logits, new_cache = transformer.mixed_step(
                params, cfg, tokens, cache, pos, pages)
            tok, conf = pick(logits)
            return tok, conf, new_cache

        def ragged_fn(params, tokens, cache, pos, page_table, q_len,
                      q_start):
            # ragged flat token-batch step: the tick's live tokens packed
            # contiguously in [1, W] (W bucketed), so compute is O(live
            # tokens) instead of O(capacity * width); returns per-row
            # last-position picks in engine-row order like mixed_fn
            pages = {"page_table": page_table, "q_len": q_len,
                     "q_start": q_start}
            logits, new_cache = transformer.ragged_step(
                params, cfg, tokens, cache, pos, pages)
            tok, conf = pick(logits)
            return tok, conf, new_cache

        k = self.spec_k
        do_draft = self.spec_draft

        def spec_fn(params, tokens, cache, pos, page_table, q_len,
                    q_start, draft_len):
            # speculative ragged step: the ragged forward keeps *all*
            # per-position logits so verify rows (q_len = 1 + n) score
            # every drafted position, the fused spec_accept epilogue
            # decides acceptance device-side, and (draft tiers only) a
            # k-1 step decode scan extends each drafting row's catch-up
            # pick into k draft tokens — one compiled program, one fetch
            pages = {"page_table": page_table, "q_len": q_len,
                     "q_start": q_start}
            logits, new_cache = transformer.ragged_verify(
                params, cfg, tokens, cache, pos, pages)
            am, cf = pick(logits[0])
            out = kernel_ops.spec_accept(am, cf, q_len, tokens, k)
            tok, conf = out["tok"], out["conf"]
            draft_tok = jnp.zeros((q_len.shape[0], k), jnp.int32)
            draft_conf = jnp.zeros((q_len.shape[0], k), jnp.float32)
            if do_draft:
                def body(carry, j):
                    cache_c, cur_tok, cur_pos = carry
                    # rows whose per-row draft budget is spent (or that
                    # aren't drafting) mask to the null block: their
                    # writes and outputs are discarded
                    live = draft_len > j
                    pt = jnp.where(live[:, None], page_table, 0)
                    dl, cache_c = transformer.decode_step(
                        params, cfg, cur_tok[:, None], cache_c,
                        jnp.where(live, cur_pos, 0)[:, None],
                        pages={"page_table": pt})
                    t2, c2 = pick(dl[:, 0])
                    return (cache_c, t2, cur_pos + 1), (t2, c2)

                if k > 1:
                    # q_start is each row's starting *sequence* position,
                    # so q_start + q_len is where its first scan step
                    # writes (one past the catch-up chunk)
                    (new_cache, _, _), (dt, dc) = jax.lax.scan(
                        body, (new_cache, tok, q_start + q_len),
                        jnp.arange(1, k))
                    draft_tok = jnp.concatenate([tok[None], dt]).T
                    draft_conf = jnp.concatenate([conf[None], dc]).T
                else:
                    draft_tok = tok[:, None]
                    draft_conf = conf[:, None]
            return (tok, conf, out["spec_tok"], out["spec_conf"],
                    out["acc_len"], draft_tok, draft_conf, new_cache)

        self.prefill_fn = jax.jit(prefill_fn)
        # Donate the cache so XLA updates the slot arena in place instead
        # of copying it every token (2x peak cache memory otherwise).  CPU
        # ignores donation and warns, so only donate on accelerators.
        donate = (2,) if jax.default_backend() != "cpu" else ()
        self.step_fn = jax.jit(step_fn, donate_argnums=donate)
        self.chunk_fn = jax.jit(chunk_fn, donate_argnums=donate)
        self.mixed_fn = jax.jit(mixed_fn, donate_argnums=donate)
        self.ragged_fn = jax.jit(ragged_fn, donate_argnums=donate)
        self.spec_fn = (jax.jit(spec_fn, donate_argnums=donate)
                        if self.spec_k and self.ragged else None)

    # -- ragged flat-width buckets ------------------------------------------

    def _default_buckets(self) -> List[int]:
        """Powers of two from 8 up to the first covering the worst-case
        tick (every row prefilling a full chunk = capacity * chunk live
        tokens)."""
        worst = max(self.capacity * self.chunk, 1)
        buckets, w = [], 8
        while w < worst:
            buckets.append(w)
            w *= 2
        buckets.append(w)
        return buckets

    def _validate_buckets(self, buckets: Sequence[int]) -> List[int]:
        out = sorted({int(b) for b in buckets})
        if not out or out[0] <= 0:
            raise ValueError(f"flat_buckets must be positive: {buckets}")
        for b in out:
            if b > 16 and b % 16:
                raise ValueError(
                    f"flat bucket {b} must be a multiple of the ragged "
                    "kernel's 16-token query tile (widths <= 16 are "
                    "single-tile and exempt)")
        worst = self.capacity * self.chunk
        if out[-1] < worst:
            raise ValueError(
                f"largest flat bucket {out[-1]} cannot cover the "
                f"worst-case tick of {worst} live tokens "
                f"({self.capacity} slots x {self.chunk}-token chunks)")
        return out

    def bucket_width(self, live_tokens: int) -> int:
        """Smallest bucket holding `live_tokens` (>= 1 slot)."""
        need = max(int(live_tokens), 1)
        for b in self.flat_buckets:
            if b >= need:
                return b
        return self.flat_buckets[-1]

    # -- device placement ---------------------------------------------------

    def _place_params(self, spec: TierSpec):
        """Params on the tier mesh: replicated, or tensor-sharded over
        'model' per the MaxText-style logical-axis rules when
        ``spec.shard_params``."""
        if spec.mesh is None:
            return spec.params
        if spec.shard_params:
            shardings = jax.tree.map(
                lambda ps: NamedSharding(spec.mesh, ps),
                params_lib.param_specs(spec.cfg, spec.mesh),
                is_leaf=lambda x: isinstance(x, PartitionSpec))
        else:
            shardings = jax.tree.map(
                lambda _: NamedSharding(spec.mesh, PartitionSpec()),
                spec.params)
        return jax.device_put(spec.params, shardings)

    def put_rows(self, arr):
        """A per-tick host array onto the tier's devices, row dim sharded
        over the data axes (no mesh: plain transfer).  Used for tokens,
        positions, chunk batches, and page tables — and thereby the
        escalation transfer path: a request escalated from another tier
        is packed into this tier's fixed-shape batches on the host and
        placed under *this* tier's sharding here."""
        arr = np.asarray(arr)
        if self.mesh is None:
            return jnp.asarray(arr)
        spec = PartitionSpec(*(("data",) + (None,) * (arr.ndim - 1)))
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def _ctx(self):
        """The tier's mesh context (shard_hint constraints resolve
        against it); a no-op without a mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return sharding_lib.set_mesh(self.mesh)

    def run_prefill(self, prompts):
        with self._ctx():
            return self.prefill_fn(self.params, self.put_rows(prompts))

    def run_chunk(self, tokens, pos, qlen):
        with self._ctx():
            return self.chunk_fn(
                self.params, self.put_rows(tokens), self.pool.cache,
                self.put_rows(pos), self.page_table_device(),
                self.put_rows(qlen))

    def run_step(self, tok_dev, mask_rows):
        with self._ctx():
            return self.step_fn(
                self.params, tok_dev, self.pool.cache,
                self.put_rows(self.pos[:, None]),
                self.page_table_device(mask_rows=mask_rows))

    def run_mixed(self, tokens, pos, qlen):
        """The padded unified token-batch launch: one compiled program
        serves every live row's tick — prefill chunks and decode tokens
        share the batch, so no page-table masking is needed (each row
        scatters into and attends its *own* pages inside the same
        program)."""
        self.launched_widths.add(int(np.asarray(tokens).shape[1]))
        with self._ctx():
            return self.mixed_fn(
                self.params, self.put_rows(tokens), self.pool.cache,
                self.put_rows(pos), self.page_table_device(),
                self.put_rows(qlen))

    def put_flat(self, arr):
        """A flat ``[1, W]`` per-tick array onto the tier's devices,
        replicated (the leading dim is not the row dim, so it cannot
        shard over the data axes; GSPMD mixes the replicated flat batch
        with the row-sharded page table and KV arena)."""
        arr = np.asarray(arr)
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(arr, NamedSharding(self.mesh,
                                                 PartitionSpec()))

    def run_ragged(self, flat_tokens, flat_pos, qlen, qstart):
        """The ragged flat token-batch launch: ONE compiled program at a
        bucketed flat width serves the tick's live tokens — each token
        scatters KV through and attends its owning row's pages, so the
        program's compute is O(live tokens), not O(capacity * width)."""
        self.launched_widths.add(int(np.asarray(flat_tokens).shape[1]))
        with self._ctx():
            return self.ragged_fn(
                self.params, self.put_flat(flat_tokens), self.pool.cache,
                self.put_flat(flat_pos), self.page_table_device(),
                self.put_rows(qlen), self.put_rows(qstart))

    def run_spec(self, flat_tokens, flat_pos, qlen, qstart, draft_len):
        """The speculative ragged launch (``speculation_k > 0``): the
        same flat token-batch contract as :meth:`run_ragged`, plus the
        per-row draft budget ``draft_len [capacity]`` driving the fused
        draft scan.  Still ONE compiled program per tier per tick."""
        self.launched_widths.add(int(np.asarray(flat_tokens).shape[1]))
        with self._ctx():
            return self.spec_fn(
                self.params, self.put_flat(flat_tokens), self.pool.cache,
                self.put_flat(flat_pos), self.page_table_device(),
                self.put_rows(qlen), self.put_rows(qstart),
                self.put_rows(draft_len))

    def page_table_device(self, mask_rows: Sequence[int] = ()):
        """Device page tables; ``mask_rows`` (rows mid-prefill during a
        decode step) have their pages unmapped in the copy so the decode
        scatter/gather for those rows hits the null block instead of the
        blocks their prefill chunks are filling."""
        if self.paged:
            pt = self.pool.page_table
            if len(mask_rows):
                pt = pt.copy()
                pt[list(mask_rows)] = 0
            return self.put_rows(pt)
        # dense pools take a dummy (the traced fn ignores it)
        return self.put_rows(np.zeros((self.capacity, 1), np.int32))

    def occupied(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is not None]

    def decoding(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req)
                if r is not None and r.state is RequestState.DECODE
                and not r.decode_finished]

    def prefilling(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req)
                if r is not None and r.state is RequestState.PREFILL]

    def draft_slots(self) -> List[int]:
        """Rows retained as draft rows for escalated requests."""
        return [s for s, r in enumerate(self.draft_req) if r is not None]


class _RetryExhausted(RuntimeError):
    """Internal: a launch's bounded retry budget ran out on persistent
    transient errors.  The engine catches this at each launch site and
    sacrifices a single victim request — never the run."""

    def __init__(self, kind: str, cause: BaseException):
        super().__init__(f"launch retries exhausted in {kind}: {cause}")
        self.kind = kind
        self.cause = cause


def _transient_error_types() -> tuple:
    """Exception classes the retry wrapper treats as transient: injected
    :class:`repro.serving.faults.TransientError` always, plus the running
    jax's runtime-error class (transfer hiccups, collective timeouts)
    when it exposes one."""
    types = [faults_lib.TransientError]
    jax_err = getattr(getattr(jax, "errors", None), "JaxRuntimeError", None)
    if jax_err is not None:
        types.append(jax_err)
    return tuple(types)


class CascadeEngine:
    """M-tier cascade with continuous batching and per-request gating."""

    def __init__(self, tiers: Sequence[TierSpec], *,
                 slots: int | Sequence[int] = 8,
                 prompt_len: int = 32, gen_len: int = 16,
                 deltas: Optional[Sequence[float]] = None,
                 escalation_budget: Optional[float] = None,
                 conf_reduce: str = "mean",
                 use_gate_kernel: bool = True,
                 use_paged_kv: bool = True,
                 kv_block_size: int = 16,
                 kv_blocks: Optional[int | Sequence[Optional[int]]] = None,
                 use_chunked_prefill: Optional[bool] = None,
                 prefill_chunk: int = 128,
                 prefill_token_budget: Optional[int] = None,
                 use_unified_step: Optional[bool] = None,
                 use_ragged_step: Optional[bool] = None,
                 flat_buckets: Optional[Sequence[int]] = None,
                 prefix_cache: bool = False,
                 speculation_k: int = 0,
                 spec_delta: Optional[float] = None,
                 tracer: Optional[obs.Tracer] = None,
                 profile_annotations: bool = False,
                 clock=None,
                 preemption_policy: str = "none",
                 launch_retries: int = 2,
                 retry_backoff: float = 0.02,
                 faults: Optional[faults_lib.FaultPlan] = None):
        """``use_paged_kv`` selects the block-paged KV arena + Pallas
        paged flash-decode kernel (interpret mode off-TPU); False keeps
        the PR 1 dense one-page-per-request arena (the reference path).
        ``kv_blocks`` sizes each tier's arena in KV *blocks* of
        ``kv_block_size`` tokens — None fully provisions
        (``slots * ceil(max_seq / block_size) + 1``); a smaller count
        over-subscribes the arena: admission is then block-limited and
        rows may stall a tick waiting for a free block (attention-only
        models; recurrent state cannot replay a stalled step).

        ``use_chunked_prefill`` (default: auto — on whenever the arena is
        paged and every tier is attention-only with no modality frontend)
        replaces the dense packed prefill with **chunked paged prefill**:
        ``prompt_len`` becomes the *maximum* prompt length, ``submit``
        accepts any length in ``[1, prompt_len]``, and each admitted row
        advances ``prefill_chunk`` prompt tokens per tick written directly
        into its KV blocks.  Admission is bounded by
        ``prefill_token_budget`` prompt tokens per tier per tick (default
        ``slots * prefill_chunk``).  ``use_chunked_prefill=False`` keeps
        the uniform-length packed prefill (exact ``prompt_len`` enforced
        at submit) — the bit-exactness oracle for the chunked path.

        ``use_unified_step`` (default: auto — on exactly when chunked
        prefill is on) selects **unified token-batch execution**: each
        tick builds one flat token batch in which every live row
        contributes its next prefill chunk or its single decode token,
        executed by ONE compiled mixed-attention program per tier per
        tick (``transformer.mixed_step`` over
        ``kernels/mixed_attention.py``) with one blocking ``device_get``.
        The per-tick token budget then spans prefill chunks *and* decode
        tokens uniformly: admission charges a request's first chunk
        against the same currency the tick's carried decode+chunk load
        already occupies.  ``use_unified_step=False`` is the split-path
        escape hatch (legacy ``chunk_fn`` + ``step_fn``, two launches on
        mixed ticks) — the A/B baseline; token streams are bit-identical
        between the two.

        ``use_ragged_step`` (default: auto — on exactly when unified
        execution is on) selects the **ragged flat token-batch layout**
        inside unified execution: each tick's live tokens are packed
        contiguously into one ``[1, W]`` flat batch (W drawn from a
        small power-of-two bucket set, ``flat_buckets``), executed by
        ONE compiled ragged-attention program per tier per tick
        (``transformer.ragged_step`` over
        ``kernels/ragged_attention.py``) whose compute is O(live
        tokens) end-to-end — idle slots cost nothing instead of a
        padded row.  All bucket widths compile at :meth:`warmup`, so a
        mixed-length run never recompiles mid-run
        (:meth:`compile_stats`).  ``use_ragged_step=False`` keeps the
        padded ``[capacity, width]`` mixed program — the bit-identical
        escape hatch and A/B baseline; ``flat_buckets`` overrides the
        bucket set (each width > 16 must be a multiple of the kernel's
        16-token query tile, and the largest must cover
        ``capacity * prefill_chunk``).

        ``tracer`` attaches a :class:`repro.serving.observability.Tracer`:
        the engine then records per-request lifecycle spans and per-tick
        phase events (admit / plan / launch / device_get / finish) into
        its ring buffer for Chrome-trace export.  ``tracer=None``
        (default) is zero-cost — every trace call site is guarded, no
        event objects are built, and no extra host syncs happen either
        way (events only use values the tick already fetched;
        test-asserted).  ``profile_annotations`` additionally wraps each
        tick in ``jax.profiler.StepTraceAnnotation`` (step_num = tick
        id) and each launch in a named ``TraceAnnotation`` so an opt-in
        device-profiler window correlates with the host tracer.

        ``prefix_cache`` turns on **refcounted prefix caching** (requires
        the chunked block-paged path): each tier's pool keeps a
        per-shard prefix index over chunk-aligned prompt prefixes, and
        admission matches a submitted prompt's longest cached prefix,
        maps those KV blocks read-only into the new row's page table
        (copy-on-write isolates any block a boundary splits), and starts
        chunked prefill at the first uncached token — cached tokens cost
        0 prefill work and 0 admission budget.  Completed chunk
        boundaries are published back to the index as rows prefill;
        eviction is refcount-aware LRU (docs/serving.md "Prefix
        caching").  Token streams are bit-identical with the cache on or
        off under a fixed-δ gate: shared KV equals what re-prefilling
        the same tokens would write, and greedy decode is deterministic.

        ``preemption_policy`` trades stalls for evictions when the KV
        block pool runs dry (docs/serving.md "Overload and failure
        semantics"): ``youngest`` evicts the most recently bound row on
        a stalled shard, ``fewest-tokens`` the least-progressed one; the
        victim re-queues at the head of its tier's queue and replays
        prefill+decode from scratch (bit-identical — greedy decode is
        deterministic).  Requires the chunked block-paged path; a
        shard's oldest bound row is never evicted, so the oldest-first
        termination argument survives.  ``launch_retries`` bounds the
        retry-with-backoff wrapper around every launch and ``device_get``
        (``retry_backoff`` seconds, doubling); when retries exhaust the
        engine fails one victim request, never the run.  ``faults``
        attaches a :class:`repro.serving.faults.FaultPlan` — zero-cost
        when None, like the tracer."""
        if not tiers:
            raise ValueError("need at least one tier")
        self.tiers = list(tiers)
        m = len(self.tiers)
        chunkable = use_paged_kv and all(
            not cache_lib.has_recurrent_state(t.cfg) and t.cfg.frontend
            is None for t in self.tiers)
        if use_chunked_prefill is None:
            use_chunked_prefill = chunkable
        elif use_chunked_prefill and not chunkable:
            raise ValueError(
                "chunked prefill requires the block-paged KV arena "
                "(use_paged_kv=True) and attention-only tiers without a "
                "modality frontend (recurrent state cannot be carried "
                "across prefill chunks)")
        self.chunked_prefill = use_chunked_prefill
        if use_unified_step is None:
            use_unified_step = use_chunked_prefill
        elif use_unified_step and not use_chunked_prefill:
            raise ValueError(
                "unified token-batch execution requires chunked paged "
                "prefill (use_paged_kv=True, attention-only tiers); dense "
                "and recurrent-state tiers keep the legacy split "
                "chunk+decode path (use_unified_step=False)")
        self.unified_step = use_unified_step
        if use_ragged_step is None:
            use_ragged_step = use_unified_step
        elif use_ragged_step and not use_unified_step:
            raise ValueError(
                "the ragged flat token-batch layout runs inside unified "
                "token-batch execution (use_unified_step=True); the split "
                "and dense paths have no flat batch to pack")
        self.ragged_step = bool(use_ragged_step) and use_unified_step
        if flat_buckets is not None and not self.ragged_step:
            raise ValueError(
                "flat_buckets sizes the ragged flat layout's compiled "
                "widths; it requires use_ragged_step")
        if prefix_cache and not use_chunked_prefill:
            raise ValueError(
                "prefix caching requires chunked paged prefill "
                "(use_paged_kv=True, attention-only tiers): shared prefix "
                "blocks are matched and published at chunk boundaries, and "
                "the resumed prefill starts mid-prompt")
        self.prefix_cache = bool(prefix_cache)
        if speculation_k:
            if speculation_k < 0:
                raise ValueError("speculation_k must be >= 0")
            if m < 2:
                raise ValueError(
                    "speculative cascade decoding needs at least two "
                    "tiers: a cheap tier to draft and an expensive tier "
                    "to verify")
            if not self.ragged_step:
                raise ValueError(
                    "speculative cascade decoding requires the ragged "
                    "flat token-batch layout (use_ragged_step=True): the "
                    "verify pass scores k+1 positions per row through "
                    "the arbitrary-q_len work list")
        if spec_delta is not None and not speculation_k:
            raise ValueError(
                "spec_delta truncates staged drafts; it requires "
                "speculation_k > 0")
        self.speculation_k = int(speculation_k)
        self.spec_delta = None if spec_delta is None else float(spec_delta)
        if prefill_chunk <= 0:
            raise ValueError("prefill_chunk must be positive")
        slots_per_tier = ([int(slots)] * m if np.isscalar(slots)
                          else [int(s) for s in slots])
        kv_blocks_per_tier = (
            [kv_blocks] * m if kv_blocks is None or np.isscalar(kv_blocks)
            else [None if b is None else int(b) for b in kv_blocks])
        if len(slots_per_tier) != m or len(kv_blocks_per_tier) != m:
            raise ValueError(
                f"per-tier sequences must match the {m} tiers: got "
                f"{len(slots_per_tier)} slots, "
                f"{len(kv_blocks_per_tier)} kv_blocks entries")
        if deltas is not None:
            gates = [GateSpec(delta=float(d)) for d in deltas]
        elif escalation_budget is not None:
            gates = [GateSpec(budget=float(escalation_budget))
                     for _ in range(m - 1)]
        else:
            gates = [GateSpec(delta=0.5) for _ in range(m - 1)]
        if len(gates) != m - 1:
            raise ValueError("one gate per non-final tier")

        self.prompt_len = prompt_len        # chunked: max prompt length
        self.gen_len = gen_len
        self.conf_reduce = conf_reduce
        self.prefill_chunk = min(prefill_chunk, prompt_len)
        self.prefill_token_budget = (
            prefill_token_budget if prefill_token_budget is not None
            else max(slots_per_tier) * self.prefill_chunk)
        # sharded serving: each tier's rows partition over its mesh's
        # data shards; admission targets the shard whose block pool can
        # take the request (validated against slots in _TierRuntime)
        shards_per_tier = [t.data_shards() for t in self.tiers]
        self.metrics = ServingMetrics(
            [TierCost(t.name, t.flops_per_request(gen_len))
             for t in self.tiers], slots_per_tier)
        # the scheduler streams every gate decision into the metrics'
        # calibration telemetry; the engine streams escalation outcomes
        self.scheduler = CascadeScheduler(
            slots_per_tier, gates, shards_per_tier,
            calibration=self.metrics.calibration)
        self.clock = clock if clock is not None else WallClock()
        self.tracer = tracer
        self.profile_annotations = bool(profile_annotations)
        self.tick_id = 0
        if tracer is not None:
            tracer.name_process(obs.ENGINE_PID, "engine ticks")
            # tid layout on the engine pid: one lane per tier, plus a
            # whole-tick umbrella lane at tid = num_tiers
            tracer.name_track(obs.ENGINE_PID, len(self.tiers), "tick")
            for i, t in enumerate(self.tiers):
                tracer.name_track(obs.ENGINE_PID, i, f"tier{i} {t.name}")
                tracer.name_process(obs.REQUEST_PID_BASE + i,
                                    f"requests tier{i} {t.name}")
        max_seq = prompt_len + gen_len
        if use_paged_kv:
            ppr = math.ceil(max_seq / kv_block_size)
            for spec, cap, nb in zip(self.tiers, slots_per_tier,
                                     kv_blocks_per_tier):
                if nb is not None and nb < cap * ppr + 1 \
                        and cache_lib.has_recurrent_state(spec.cfg):
                    raise ValueError(
                        f"tier {spec.name}: kv_blocks={nb} over-subscribes "
                        "the arena but the model carries recurrent state "
                        "(mamba/rwkv), which cannot replay a stalled "
                        "decode step — use full provisioning (kv_blocks="
                        "None)")
        self.runtimes = [
            _TierRuntime(spec, cap, prompt_len, max_seq, use_gate_kernel,
                         use_paged_kv=use_paged_kv, block_size=kv_block_size,
                         kv_blocks=nb,
                         use_chunked_prefill=use_chunked_prefill,
                         prefill_chunk=self.prefill_chunk,
                         use_unified_step=use_unified_step,
                         use_ragged_step=self.ragged_step,
                         flat_buckets=flat_buckets,
                         prefix_cache=prefix_cache,
                         speculation_k=self.speculation_k,
                         spec_draft=(i < m - 1))
            for i, (spec, cap, nb) in enumerate(
                zip(self.tiers, slots_per_tier, kv_blocks_per_tier))]
        self.requests: List[Request] = []
        self._rid = 0
        # per-tier token-budget window state, reset each tick: tokens
        # charged (unified: seeded with the tick's carried decode+chunk
        # load — one currency) and requests admitted (never-starve guard)
        self._budget_used = [0] * m
        self._admitted = [0] * m
        self.host_syncs = 0                 # blocking device->host fetches
        # -- overload & failure layer (module docstring) -------------------
        if preemption_policy not in ("none", "youngest", "fewest-tokens"):
            raise ValueError(
                f"unknown preemption_policy {preemption_policy!r} "
                "(choose none / youngest / fewest-tokens)")
        if preemption_policy != "none" and not use_chunked_prefill:
            raise ValueError(
                "preemption requires the block-paged arena with chunked "
                "prefill: the replay path re-runs the victim's prefill "
                "through the idempotent chunk machinery")
        self.preemption_policy = preemption_policy
        if launch_retries < 0:
            raise ValueError("launch_retries must be >= 0")
        self.launch_retries = int(launch_retries)
        self.retry_backoff = float(retry_backoff)
        self.faults = faults
        self._transient = _transient_error_types()
        self._has_deadlines = False         # any submit carried a deadline
        self._min_tick_dt: Optional[float] = None   # shedding floor unit
        self._last_tick_t: Optional[float] = None
        self._last_stalls = [0] * m         # per tier, for drain diagnostics

    # -- submission --------------------------------------------------------

    def submit(self, prompt, arrival_time: float = 0.0,
               deadline: Optional[float] = None) -> Request:
        """Queue one request.  ``deadline`` (absolute, in the engine's
        clock domain) opts it into load shedding: the per-tick shedding
        pass rejects it (terminal ``SHED``) once the deadline has passed
        or provably cannot be met (see :meth:`_service_floor`)."""
        prompt = np.asarray(prompt, np.int32)
        if self.chunked_prefill:
            if prompt.ndim != 1 or not 1 <= prompt.shape[0] <= self.prompt_len:
                raise ValueError(
                    f"prompt must be 1D with 1..{self.prompt_len} tokens, "
                    f"got shape {prompt.shape}")
        elif prompt.shape != (self.prompt_len,):
            raise ValueError(
                f"prompt must be [{self.prompt_len}], got {prompt.shape} "
                "(the uniform packed prefill batches one prompt length; "
                "use chunked prefill for mixed lengths)")
        req = Request(rid=self._rid, prompt=prompt, gen_len=self.gen_len,
                      arrival_time=float(arrival_time),
                      deadline=None if deadline is None else float(deadline))
        self._rid += 1
        self.requests.append(req)
        self.scheduler.submit(req)
        self.metrics.record_submitted()
        if deadline is not None:
            self._has_deadlines = True
        if self.tracer is not None:
            self.tracer.request_transition(
                req.rid, "QUEUED", 0, prompt_tokens=req.prompt_tokens)
        return req

    # -- one engine tick ---------------------------------------------------

    def _fetch(self, tier: int, tree):
        """The tick's blocking device->host transfer (counted overall and
        per tier: the sync-coalescing tests assert a mixed prefill+decode
        tick pays exactly one of these per active tier).  Traced as the
        ``device_get`` phase — its duration is where device compute the
        host must wait for shows up on the timeline.  Runs under the
        retry wrapper (side-effect-free: re-fetching re-reads the same
        device buffers); exhaustion here is engine-fatal — the tick's
        results are unrecoverable without the transfer."""
        self.host_syncs += 1
        self.metrics.record_host_sync(tier)
        tr = self.tracer
        if tr is None:
            return self._launch(tier, "device_get",
                                lambda: jax.device_get(tree))
        t0 = tr.now_us()
        out = self._launch(tier, "device_get", lambda: jax.device_get(tree))
        tr.phase("device_get", tier, t0, tick=self.tick_id)
        return out

    def _launch(self, tier: int, kind: str, thunk):
        """Run one launch/transfer under bounded retry-with-backoff.
        Transient failures (an injected
        :class:`repro.serving.faults.TransientError`, or jax's runtime
        error class) retry up to ``launch_retries`` times with doubling
        ``retry_backoff``; relaunching is safe because the tick's plan is
        pure host data built *before* any host state advances — replaying
        it rewrites the same KV pages idempotently.  Exhaustion raises
        :class:`_RetryExhausted` for the call site to sacrifice a single
        victim request (see :meth:`_fail_one`)."""
        delay = self.retry_backoff
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    self.faults.pre_launch(self.tick_id, tier, kind, attempt)
                return thunk()
            except self._transient as e:
                if self.tracer is not None:
                    self.tracer.instant("launch_retry", tier,
                                        tick=self.tick_id, kind=kind,
                                        attempt=attempt, error=str(e))
                if attempt >= self.launch_retries:
                    raise _RetryExhausted(kind, e) from e
                self.metrics.record_retry(tier)
                attempt += 1
                if delay > 0:
                    time.sleep(delay)
                    delay *= 2

    def _pick_shard(self, tier: int, rt: _TierRuntime,
                    ntokens: int) -> Optional[int]:
        """The data shard the next admission should land on: a shard with
        a free request row whose block pool passes ``can_admit`` for the
        request's first pages, preferring the most free blocks (lowest
        shard id on ties).  None when no shard can take it — single-shard
        tiers degrade to the plain row+block check."""
        alloc = self.scheduler.allocators[tier]
        best, best_free = None, -1
        for s in range(rt.data_shards):
            if alloc.free_in(s) == 0 or not rt.pool.can_admit(ntokens, s):
                continue
            free = rt.pool.blocks.free_in(s)
            if free > best_free:
                best, best_free = s, free
        return best

    def _pick_shard_prefix(self, tier: int, rt: _TierRuntime, req: Request):
        """Chunked admission's shard choice plus the longest cached
        prefix there, as ``(shard, cached_tokens, blocks)``.  Among
        shards with a free row whose pool passes ``can_admit``, prefer
        the longest prefix match, then the most free blocks (lowest
        shard id on ties) — with the cache off this reduces exactly to
        :meth:`_pick_shard`.  A shard whose pool cannot take the request
        *with* its match (the pinned blocks stop being LRU-evictable) is
        retried without it, so caching never blocks an admission the
        uncached path would have made."""
        alloc = self.scheduler.allocators[tier]
        plen = req.prompt_tokens
        best = None
        for s in range(rt.data_shards):
            if alloc.free_in(s) == 0:
                continue
            cached, blocks = (rt.pool.match_prefix(req.prompt, s)
                              if rt.prefix else (0, []))
            span = cached + min(rt.chunk, plen - cached)
            if not rt.pool.can_admit(span, s, cached=cached,
                                     prefix_blocks=blocks):
                if not cached or not rt.pool.can_admit(
                        min(rt.chunk, plen), s):
                    continue
                cached, blocks = 0, []
            key = (cached, rt.pool.blocks.free_in(s), -s)
            if best is None or key > best[0]:
                best = (key, s, cached, blocks)
        if best is None:
            return None, 0, []
        return best[1], best[2], best[3]

    def _trace_req(self, req: Request, state: str,
                   tier: int, shard: Optional[int]) -> None:
        if self.tracer is not None:
            self.tracer.request_transition(req.rid, state, tier, shard,
                                           tick=self.tick_id)

    def _admit(self, tier: int, now: float) -> None:
        """Admission, traced as the tick's ``admit`` phase (both the
        leading and the trailing pass emit one event each)."""
        tr = self.tracer
        if tr is None:
            return self._admit_requests(tier, now)
        t0 = tr.now_us()
        before = self.metrics.tier_requests[tier]
        self._admit_requests(tier, now)
        tr.phase("admit", tier, t0, tick=self.tick_id,
                 admitted=self.metrics.tier_requests[tier] - before)

    def _admit_requests(self, tier: int, now: float) -> None:
        rt = self.runtimes[tier]
        if rt.chunked:
            # mixed-length admission: bind rows one at a time, bounded by
            # free rows, free KV blocks for the *first chunk* (later
            # chunks grow lazily) on the target data shard, and the
            # tier's token budget per tick (scheduler-enforced; the
            # budget window spans both admission passes of a tick via
            # _budget_used, and the window's first admitted request is
            # always admitted so a prompt longer than the whole budget
            # cannot starve).  Unified tiers reason in ONE currency: the
            # window is pre-charged with the tick's carried load (decode
            # tokens + in-flight prefill chunks, see _tick_load) and a
            # new request bills only its first chunk — later chunks
            # occupy later ticks' windows.  Legacy split tiers keep the
            # old accounting (full prompt length, prefill-only window).
            # No compute here — the token batch runs in _tier_step.
            fresh = 0
            while True:
                head = self.scheduler.peek(tier, now)
                if head is None:
                    break
                plen = head.prompt_tokens
                # a preempted request being re-admitted replays work the
                # metrics already counted: don't re-record the admission
                # (Eq 7 cost and stats.requests stay per-request); the
                # replayed compute is visible as replayed_tokens instead
                replay = head.state is RequestState.PREEMPTED
                shard, cached, pblocks = \
                    self._pick_shard_prefix(tier, rt, head)
                if shard is None:
                    break
                # admission billing skips the cached prefix entirely:
                # unified tiers charge the first *uncached* chunk, split
                # tiers the uncached suffix — cached chunks cost 0
                cost = ((lambda r, c=cached:
                         min(rt.chunk, r.prompt_tokens - c))
                        if rt.unified else
                        (lambda r, c=cached: r.prompt_tokens - c)
                        if cached else None)
                reqs, slot_ids = self.scheduler.admit(
                    tier, now, limit=1,
                    token_budget=self.prefill_token_budget,
                    budget_used=self._budget_used[tier],
                    admitted_before=(self._admitted[tier] if rt.unified
                                     else None),
                    token_cost=cost, shard=shard)
                if not reqs:
                    break               # over budget this tick
                req, slot = reqs[0], slot_ids[0]
                rt.pool.bind(slot, cached + min(rt.chunk, plen - cached),
                             row_tokens=plen + self.gen_len,
                             prefix=(cached, pblocks) if cached else None)
                rt.slot_req[slot] = req
                # chunked prefill resumes at the first uncached token
                rt.prefill_pos[slot] = cached
                self._trace_req(req, "PREFILL", tier, shard)
                if rt.prefix:
                    self.metrics.record_prefix_lookup(tier, cached, plen)
                    if self.tracer is not None:
                        self.tracer.prefix_cache_event(
                            tier, req.rid, cached, plen,
                            tick=self.tick_id, shard=shard)
                self._budget_used[tier] += (min(rt.chunk, plen - cached)
                                            if rt.unified
                                            else plen - cached)
                self._admitted[tier] += 1
                fresh += 0 if replay else 1
            if fresh:
                self.metrics.record_admission(tier, fresh)
            return
        if rt.paged:
            # block-aware admission: one request at a time, binding its
            # prompt pages on the picked shard, until rows, blocks, or
            # the queue run out (can_admit leaves the shard's oldest row
            # its worst-case remaining demand — the discipline that makes
            # over-subscription deadlock-free; see serving.slots)
            reqs, slot_ids = [], []
            while self.scheduler.peek(tier, now) is not None:
                shard = self._pick_shard(tier, rt, self.prompt_len)
                if shard is None:
                    break
                r, s = self.scheduler.admit(tier, now, limit=1, shard=shard)
                if not r:
                    break
                rt.pool.bind(s[0], self.prompt_len)
                reqs += r
                slot_ids += s
        else:
            reqs, slot_ids = self.scheduler.admit(tier, now)
        if not reqs:
            return
        self.metrics.record_admission(tier, len(reqs))
        self.metrics.record_prefill_tokens(
            len(reqs) * self.prompt_len, rt.capacity * self.prompt_len)
        tr = self.tracer
        t0 = tr.now_us() if tr is not None else 0.0
        while True:
            prompts = np.zeros((rt.capacity, self.prompt_len), np.int32)
            for i, req in enumerate(reqs):
                prompts[i] = req.prompt
            try:
                with obs.annotation(f"run_prefill/{rt.spec.name}",
                                    self.profile_annotations):
                    part_cache, ftok, fconf = self._launch(
                        tier, "run_prefill",
                        lambda p=prompts: rt.run_prefill(p))
                break
            except _RetryExhausted as e:
                # rows aren't populated yet (slot_req assigns below), so
                # the sacrifice is simple: drop the youngest admission
                # and relaunch the remaining prompts
                req, slot = reqs.pop(), slot_ids.pop()
                req.fail(now)
                if rt.paged:
                    rt.pool.release(slot)
                self.scheduler.release(tier, slot)
                self.metrics.record_failed(tier)
                if tr is not None:
                    tr.request_done(req.rid, tier, None, state="FAILED",
                                    tick=self.tick_id, error=str(e))
                if not reqs:
                    return
        if tr is not None:
            tr.phase("launch", tier, t0, tick=self.tick_id, kind="prefill",
                     width=self.prompt_len)
        self.metrics.record_launches(tier, 1)
        rt.pool.write_prefill(slot_ids, part_cache)
        # one blocking transfer for both outputs (device_get blocks until
        # prefill finished); timestamp tokens with the post-compute clock
        # so TTFT includes prefill, not just queueing (VirtualClock is
        # constant within a step, so ticks are unaffected).  This sync is
        # separate from the tick's coalesced prefill+decode fetch: the
        # uniform one-shot path is the legacy bit-exactness oracle and
        # admits at most twice per tick, not every tick.
        ftok, fconf = self._fetch(tier, (ftok, fconf))
        t_emit = self.clock.now()
        for i, (req, slot) in enumerate(zip(reqs, slot_ids)):
            shard = rt.pool.shard_of(slot) if rt.paged else None
            self._trace_req(req, "PREFILL", tier, shard)
            req.start_decode(t_emit)
            self._trace_req(req, "DECODE", tier, shard)
            req.emit(int(ftok[i]), float(fconf[i]), t_emit)
            rt.slot_req[slot] = req
            rt.tok[slot] = ftok[i]
            rt.pos[slot] = self.prompt_len   # next decode writes here

    def _tick_load(self, rt: _TierRuntime) -> int:
        """Tokens the tier's live rows already claim this tick: one per
        decoding row plus each mid-prefill row's next chunk.  Unified
        admission pre-charges this carried load against the tick's token
        budget — prefill chunks and decode tokens are one currency."""
        load = len(rt.decoding())
        for s in rt.prefilling():
            req = rt.slot_req[s]
            load += min(rt.chunk, req.prompt_tokens - int(rt.prefill_pos[s]))
        return load

    def _build_plan(self, rt: _TierRuntime) -> Optional[StepPlan]:
        """Plan one tier's tick on the host: which rows prefill a chunk,
        which decode a token, which stall — plus the packed token batch
        the launch consumes.  Rows denied KV blocks (over-subscribed
        arena) are marked ``KIND_STALL`` and retry next tick: a stalled
        chunk replays idempotently, a stalled decode row's write lands in
        the null block and its output is discarded (over-subscription is
        rejected at construction for recurrent-state models).  Page
        tables grow lazily here — prefill rows in slot order first, then
        decode rows oldest-bound-first (matching the legacy split launch
        order; deadlock freedom itself comes from the oldest-first
        *reserve* in ``serving/slots.py``, not from this visit order).

        Under the split backend decode rows are only *listed* (their
        stall check, input token, and same-tick first-token fusion live
        in `_exec_split`, preserving the legacy launch order exactly);
        the unified backend consumes the plan verbatim."""
        pre = rt.prefilling() if rt.chunked else []
        dec = rt.decoding()
        dr = rt.draft_slots() if rt.spec_draft else []
        if not pre and not dec and not dr:
            return None
        cap = rt.capacity
        kind = np.zeros(cap, np.int8)
        qlen = np.zeros(cap, np.int32)
        shard = np.zeros(cap, np.int32)
        if rt.paged:
            for s in rt.pool.bound_rows():
                shard[s] = rt.pool.shard_of(s)
        prefill_rows: List[int] = []
        finishing: List[int] = []
        chunks: List[tuple] = []              # (slot, chunk start, length)
        for s in pre:
            req = rt.slot_req[s]
            st = int(rt.prefill_pos[s])
            n = min(rt.chunk, req.prompt_tokens - st)
            if not rt.pool.ensure_blocks(s, st + n - 1):
                kind[s] = KIND_STALL          # replay the chunk next tick
                continue
            kind[s] = KIND_PREFILL
            qlen[s] = n
            prefill_rows.append(s)
            chunks.append((s, st, n))
            if st + n == req.prompt_tokens:
                finishing.append(s)
        decode_rows: List[int] = []
        verify_rows: List[tuple] = []
        draft_rows: List[int] = []
        draft_len = np.zeros(cap, np.int32)
        dentries: List[tuple] = []            # (slot, input tokens, pos0)
        if rt.unified:
            dec_set = set(dec)
            for s in (rt.pool.bound_rows() if rt.paged else dec):
                if s not in dec_set:
                    continue
                req = rt.slot_req[s]
                p = int(rt.pos[s])
                # speculative verify: a decode row with staged drafts
                # scores its next token AND every drafted position in one
                # ragged window (q_len = 1 + nd); its KV writes for
                # rejected positions are provisional — overwritten before
                # ever attended, so rollback needs no block machinery
                nd = 0
                if rt.spec_k and req.draft_tokens:
                    nd = max(0, min(len(req.draft_tokens), rt.spec_k,
                                    self.gen_len - len(req.tokens) - 1))
                if nd > 0 and not rt.pool.ensure_blocks(s, p + nd):
                    # window denied blocks: drop the drafts (the draft
                    # row re-drafts later) and fall back to plain decode
                    req.draft_tokens = []
                    req.draft_confs = []
                    nd = 0
                if nd == 0 and rt.paged and not rt.pool.ensure_blocks(s, p):
                    kind[s] = KIND_STALL      # stall: retry next tick
                    continue
                toks = [int(rt.tok[s])]
                if nd > 0:
                    toks += [int(t) for t in req.draft_tokens[:nd]]
                    verify_rows.append((s, nd))
                kind[s] = KIND_DECODE
                qlen[s] = len(toks)
                decode_rows.append(s)
                dentries.append((s, toks, p))
        else:
            decode_rows = list(dec)
            for s in dec:
                kind[s] = KIND_DECODE
        if rt.spec_draft:
            # draft rows: catch up on the target request's emitted tokens
            # (re-processing them on this cheap tier — the scan's own KV
            # writes are always treated as garbage, so there is zero
            # rollback bookkeeping here), then draft up to spec_k tokens
            # ahead once fully caught up.  Opportunistic: a row denied
            # blocks skips the tick, it never stalls the tier.
            for s in dr:
                req = rt.draft_req[s]
                if req.state is not RequestState.DECODE or req.draft_tokens:
                    continue         # target mid-prefill / drafts pending
                base = req.prompt_tokens
                e = len(req.tokens)
                p0 = int(rt.pos[s])
                c = base + e - p0
                if c <= 0:
                    continue         # caught up; wait for emissions
                n = min(c, rt.chunk)
                kd = 0
                if n == c:           # fully caught up after this chunk
                    kd = max(0, min(rt.spec_k, self.gen_len - e - 1))
                need = max(p0 + n - 1, base + e + kd - 2)
                if not rt.pool.ensure_blocks(s, need):
                    continue
                kind[s] = KIND_DRAFT
                qlen[s] = n
                draft_len[s] = kd
                draft_rows.append(s)
                dentries.append(
                    (s, [int(t) for t in req.tokens[p0 - base:p0 - base + n]],
                     p0))
        # batch width: the chunk when any prefill row survived its block
        # check, else the widest decode/verify/draft row (1 when every
        # row is a plain decode — a tick whose prefill rows ALL stalled
        # decodes at width 1, not chunk width)
        width = rt.chunk if prefill_rows else 1
        if dentries:
            width = max(width, max(len(t) for _, t, _ in dentries))
        tokens = np.zeros((cap, width), np.int32)
        pos = np.zeros((cap, width), np.int32)
        for s, st, n in chunks:
            tokens[s, :n] = rt.slot_req[s].prompt[st:st + n]
            pos[s] = st + np.arange(width)    # row's q_start is pos[s, 0]
        for s, toks, p0 in dentries:
            tokens[s, :len(toks)] = toks
            pos[s] = p0 + np.arange(width)
        flat_width = flat_tokens = flat_pos = q_start = None
        if rt.ragged:
            # flat packing: live tokens of all rows concatenated in slot
            # order, padded up to the smallest bucket width (padding
            # scatters to the null block and emits nothing)
            flat_width = rt.bucket_width(int(qlen.sum()))
            flat_tokens = np.zeros((1, flat_width), np.int32)
            flat_pos = np.zeros((1, flat_width), np.int32)
            q_start = pos[:, 0].astype(np.int32).copy()
            o = 0
            for s in range(cap):
                n = int(qlen[s])
                if n:
                    flat_tokens[0, o:o + n] = tokens[s, :n]
                    flat_pos[0, o:o + n] = pos[s, :n]
                    o += n
        return StepPlan(width=width, kind=kind, tokens=tokens, pos=pos,
                        q_len=qlen, shard=shard, prefill_rows=prefill_rows,
                        decode_rows=decode_rows, finishing=finishing,
                        flat_width=flat_width, flat_tokens=flat_tokens,
                        flat_pos=flat_pos, q_start=q_start,
                        verify_rows=verify_rows, draft_rows=draft_rows,
                        draft_len=draft_len)

    # -- overload: preemption, load shedding, single-request failure --------

    def _pick_victim(self, rt: _TierRuntime, shard: int) -> Optional[int]:
        """The row ``preemption_policy`` evicts on `shard` when the plan
        stalled there.  Never the shard's *oldest* bound row (the
        oldest-first reserve discipline guarantees its progress — that
        guarantee is the termination argument, and it is also why the
        preempt-and-replan loop cannot livelock) and never a row whose
        decode already finished (its work is complete; this tick's gate
        frees it for nothing).  None when no candidate remains."""
        rows = [s for s in rt.pool.bound_rows()
                if rt.pool.shard_of(s) == shard]
        cands = [s for s in rows[1:]
                 if rt.slot_req[s] is not None
                 and not rt.slot_req[s].decode_finished]
        if not cands:
            return None
        if self.preemption_policy == "youngest":
            return cands[-1]
        # fewest-tokens: least total progress (prefilled + decoded);
        # the reverse scan breaks ties toward the youngest binding
        return min(reversed(cands),
                   key=lambda s: int(rt.prefill_pos[s])
                   + len(rt.slot_req[s].tokens))

    def _preempt(self, tier: int, rt: _TierRuntime, slot: int,
                 now: float) -> None:
        """Evict `slot`'s request: discard its partial tier work, free
        its blocks and row, and re-queue it at the *head* of the tier's
        queue.  Re-admission replays prefill and decode from scratch
        through the idempotent chunk machinery; greedy decode is
        deterministic, so the replayed stream is bit-identical (the
        emit-side first_token_time guard keeps TTFT at the original
        emission, matching what a streaming client observed)."""
        req = rt.slot_req[slot]
        shard = rt.pool.shard_of(slot)
        replayed = int(rt.prefill_pos[slot]) + len(req.tokens)
        self._release_draft(req)        # replay restarts decode: any
        req.preempt(now)                # retained draft row is stale
        rt.slot_req[slot] = None
        rt.tok[slot] = 0
        rt.pos[slot] = 0
        rt.prefill_pos[slot] = 0
        rt.pool.release(slot)
        self.scheduler.release(tier, slot)
        self.scheduler.requeue(req, tier)
        self.metrics.record_preemption(tier, replayed)
        self._trace_req(req, "PREEMPTED", tier, shard)

    def _release_draft(self, req: Request) -> None:
        """Free `req`'s retained draft row (if any): the cheap-tier row
        kept alive at escalation to draft tokens for the expensive
        tier's verify pass.  Idempotent; clears any staged drafts so a
        replayed / re-queued request never verifies stale tokens."""
        req.draft_tokens = []
        req.draft_confs = []
        if req.draft_slot is None:
            return
        drt = self.runtimes[req.draft_tier]
        s = req.draft_slot
        drt.draft_req[s] = None
        drt.tok[s] = 0
        drt.pos[s] = 0
        drt.prefill_pos[s] = 0
        if drt.paged:
            drt.pool.release(s)
        self.scheduler.release(req.draft_tier, s)
        req.draft_tier = None
        req.draft_slot = None

    def _preempt_stalled(self, tier: int, rt: _TierRuntime,
                         plan: Optional[StepPlan],
                         now: float) -> Optional[StepPlan]:
        """Trade stalls for evictions: while the plan has stalled rows
        and a stalled shard holds a victim, preempt one row and re-plan.
        Terminates — every pass unbinds a row, and re-planning only ever
        *frees* blocks — and cannot starve the tier, since the shard's
        oldest row is exempt and therefore always progresses."""
        while plan is not None:
            stalled = [s for s in range(rt.capacity)
                       if plan.kind[s] == KIND_STALL]
            if not stalled:
                return plan
            shards = sorted({int(plan.shard[s]) for s in stalled})
            # draft rows first: dropping one costs only speculative
            # work (its target replays nothing), so never preempt a
            # real request while a stalled shard still hosts a draft
            drafts = [s for s in rt.draft_slots()
                      if rt.pool.shard_of(s) in shards]
            if drafts:
                self._release_draft(rt.draft_req[drafts[-1]])
                plan = self._build_plan(rt)
                continue
            victim = None
            for shard in shards:
                victim = self._pick_victim(rt, shard)
                if victim is not None:
                    break
            if victim is None:
                return plan             # nothing evictable: stalls stand
            self._preempt(tier, rt, victim, now)
            plan = self._build_plan(rt)
        return plan

    def _fail_one(self, tier: int, rt: _TierRuntime, rows: Sequence[int],
                  now: float, err: Exception) -> int:
        """Retry exhaustion sacrifices ONE request so the run survives:
        the youngest-bound row among `rows` (highest row on a dense
        arena, whose binding order isn't tracked) fails terminally and
        frees its row and blocks; the caller re-plans and relaunches for
        the survivors.  Returns the victim row."""
        if rt.paged:
            order = {s: i for i, s in enumerate(rt.pool.bound_rows())}
            victim = max(rows, key=lambda s: order.get(s, -1))
        else:
            victim = max(rows)
        req = rt.slot_req[victim]
        shard = rt.pool.shard_of(victim) if rt.paged else None
        self._release_draft(req)
        req.fail(now)
        rt.slot_req[victim] = None
        rt.tok[victim] = 0
        rt.pos[victim] = 0
        rt.prefill_pos[victim] = 0
        if rt.paged:
            rt.pool.release(victim)
        self.scheduler.release(tier, victim)
        self.metrics.record_failed(tier)
        if self.tracer is not None:
            self.tracer.request_done(req.rid, tier, shard, state="FAILED",
                                     tick=self.tick_id, error=str(err))
        return victim

    def _shed(self, tier: int, now: float) -> None:
        """The load-shedding pass (zero-cost when no submitted request
        carries a deadline): reject queued requests of `tier` whose
        deadline has passed or provably cannot be met."""
        if not self._has_deadlines:
            return
        for req in self.scheduler.shed(tier, now, self._service_floor(tier)):
            self._release_draft(req)    # escalated-then-shed requests
            req.shed(now)               # may hold a cheap-tier row
            self.metrics.record_shed(tier)
            if self.tracer is not None:
                self.tracer.request_done(req.rid, tier, None, state="SHED",
                                         tick=self.tick_id)

    def _service_floor(self, tier: int):
        """A per-request lower bound on remaining service time at `tier`
        (None until a tick duration has been observed, so only
        already-expired deadlines shed): minimum ticks to finish —
        ``ceil(prompt/chunk)`` prefill ticks plus ``gen_len - 1`` decode
        ticks, minus one because the final chunk emits the first token in
        its own tick — times the *minimum* observed tick duration.  A
        true lower bound: queue wait, stalls, preemption replays, and
        escalation only add to it."""
        dt = self._min_tick_dt
        if dt is None or dt <= 0:
            return None
        rt = self.runtimes[tier]
        if rt.chunked:
            return lambda r: max(
                math.ceil(r.prompt_tokens / rt.chunk)
                + self.gen_len - 2, 0) * dt
        return lambda r: (self.gen_len - 1) * dt

    def _drain_diagnostics(self) -> str:
        """Per-tier state for the did-not-drain RuntimeError: queue
        depth, live rows, the last plan's stalled rows, and per-shard
        free blocks — enough to tell block starvation from a scheduling
        bug without attaching a debugger."""
        lines = []
        for t, rt in enumerate(self.runtimes):
            line = (f"tier {t} ({rt.spec.name}): "
                    f"queued={len(self.scheduler.queues[t])} "
                    f"live_rows={len(rt.occupied())} "
                    f"stalled_rows={self._last_stalls[t]}")
            if rt.paged:
                shards = range(rt.pool.data_shards)
                line += (" free_blocks_by_shard="
                         f"{[rt.pool.blocks.free_in(s) for s in shards]}")
                held = [rt.pool.blocks.reserved_in(s) for s in shards]
                if any(held):
                    line += f" withheld_by_shard={held}"
                if rt.prefix:
                    line += (" prefix_entries_by_shard="
                             f"{[rt.pool.prefix_index_entries(s) for s in shards]}"
                             " evictable_by_shard="
                             f"{[rt.pool.evictable_in(s) for s in shards]}")
            lines.append(line)
        return "; ".join(lines)

    def _tier_step(self, tier: int, now: float) -> int:
        """One tier's compute for a tick, planned host-side then executed
        by the unified or split backend.  Returns the number of decode
        tokens emitted (the occupancy metric)."""
        rt = self.runtimes[tier]
        tr = self.tracer
        t0 = tr.now_us() if tr is not None else 0.0
        plan = self._build_plan(rt)
        if self.preemption_policy != "none" and rt.chunked:
            plan = self._preempt_stalled(tier, rt, plan, now)
        self._last_stalls[tier] = (
            0 if plan is None else int((plan.kind == KIND_STALL).sum()))
        if plan is not None and tr is not None:
            tr.phase("plan", tier, t0, tick=self.tick_id,
                     width=plan.width,
                     prefill_rows=len(plan.prefill_rows),
                     decode_rows=len(plan.decode_rows),
                     stalled=self._last_stalls[tier])
        if plan is None:
            return 0
        if rt.unified:
            return self._exec_unified(tier, rt, plan, now)
        return self._exec_split(tier, rt, plan, now)

    def _exec_unified(self, tier: int, rt: _TierRuntime,
                      plan: StepPlan, now: float) -> int:
        """Unified token-batch execution: ONE compiled program per tier
        per tick serves every live row — each contributes its next
        prefill chunk or its single decode token (``q_len`` 0/1/chunk
        over the shared page-table gather) — and one blocking
        ``device_get`` fetches every emitted (token, confidence) pair.
        A row finishing prefill this tick emits its first token from the
        batch's last-position logits and starts decoding next tick.
        Mid-prompt-only ticks (nothing to emit) skip the fetch; ticks
        where every live row stalled skip the launch too.  The launch
        sits under the retry wrapper *before* any host state advances:
        replaying it rewrites the same KV pages idempotently, and retry
        exhaustion fails one victim, re-plans, and relaunches for the
        survivors."""
        tr = self.tracer
        use_spec = rt.spec_k > 0 and rt.ragged
        spec_out = None
        while True:
            if not plan.prefill_rows and not plan.decode_rows \
                    and not plan.draft_rows:
                return 0                # every live row stalled
            t0 = tr.now_us() if tr is not None else 0.0
            kind = ("run_spec" if use_spec
                    else "run_ragged" if rt.ragged else "run_mixed")
            try:
                with obs.annotation(f"{kind}/{rt.spec.name}",
                                    self.profile_annotations):
                    if use_spec:
                        out = self._launch(
                            tier, kind,
                            lambda p=plan: rt.run_spec(
                                p.flat_tokens, p.flat_pos, p.q_len,
                                p.q_start, p.draft_len))
                        tok, conf = out[0], out[1]
                        spec_out = out[2:7]
                        cache = out[7]
                    elif rt.ragged:
                        tok, conf, cache = self._launch(
                            tier, kind,
                            lambda p=plan: rt.run_ragged(
                                p.flat_tokens, p.flat_pos, p.q_len,
                                p.q_start))
                    else:
                        tok, conf, cache = self._launch(
                            tier, kind,
                            lambda p=plan: rt.run_mixed(p.tokens, p.pos,
                                                        p.q_len))
            except _RetryExhausted as e:
                rows = plan.prefill_rows + plan.decode_rows
                if rows:
                    self._fail_one(tier, rt, rows, now, e)
                else:
                    # a draft-only launch exhausted its retries: drop the
                    # speculation (the targets just decode normally)
                    for s in plan.draft_rows:
                        self._release_draft(rt.draft_req[s])
                plan = self._build_plan(rt)
                if plan is None:
                    return 0
                continue
            rt.pool.cache = cache
            break
        if tr is not None:
            # async dispatch: this phase is host-side launch cost (incl.
            # put_rows transfers); device wait shows under device_get
            tr.phase("launch", tier, t0, tick=self.tick_id,
                     kind="ragged" if rt.ragged else "mixed",
                     width=plan.flat_width if rt.ragged else plan.width)
        self.metrics.record_launches(tier, 1)
        # exact live-vs-processed token accounting: the ragged program
        # computes flat_width token slots (bucket padding only), the
        # padded program capacity * width
        self.metrics.record_step_tokens(
            tier, plan.live_tokens,
            plan.flat_width if rt.ragged else rt.capacity * plan.width)
        if plan.prefill_rows:
            # ragged: chunk tokens occupy exactly their live slots; the
            # bucket padding is already charged to wasted_slot_ratio
            self.metrics.record_prefill_tokens(
                plan.live_prefill_tokens,
                plan.live_prefill_tokens if rt.ragged
                else rt.capacity * plan.width)
        # host state advances on host-known lengths only; device outputs
        # stay unfetched until something must be emitted
        for s in plan.prefill_rows:
            rt.prefill_pos[s] += int(plan.q_len[s])
            if rt.prefix:
                # the launch above scattered this chunk's KV: completed
                # chunk boundaries are now publishable prefix entries
                rt.pool.publish_prefix(s, rt.slot_req[s].prompt,
                                       int(rt.prefill_pos[s]))
        t_dec = self.clock.now()
        for s in plan.finishing:
            req = rt.slot_req[s]
            req.start_decode(t_dec)
            self._trace_req(req, "DECODE", tier, int(plan.shard[s]))
            rt.pos[s] = req.prompt_tokens   # next decode writes here
        for s in plan.draft_rows:
            # catch-up advances on host-known lengths, like prefill; the
            # draft scan's own writes beyond this are always re-written
            # by the next catch-up before they could be attended
            rt.pos[s] += int(plan.q_len[s])
        drafting = [s for s in plan.draft_rows if plan.draft_len[s] > 0]
        if not plan.finishing and not plan.decode_rows and not drafting:
            return 0            # mid-prompt chunks / pure catch-up only
        if use_spec:
            fetched = self._fetch(tier, (tok, conf) + tuple(spec_out))
            tok, conf, spec_tok, spec_conf, acc_len, dtok, dconf = fetched
        else:
            tok, conf = self._fetch(tier, (tok, conf))
        t_emit = self.clock.now()       # post-compute (see _admit)
        ver = dict(plan.verify_rows)
        for s in plan.finishing + plan.decode_rows:
            req = rt.slot_req[s]
            nd = ver.get(s, 0)
            if nd:
                # greedy speculative acceptance: emit the scoring model's
                # argmax at every accepted position plus the bonus token —
                # the emitted stream is argmaxes only, bit-identical to
                # non-speculative decode
                acc = min(int(acc_len[s]), nd)
                for j in range(acc + 1):
                    req.emit(int(spec_tok[s, j]), float(spec_conf[s, j]),
                             t_emit)
                rt.tok[s] = int(spec_tok[s, acc])
                rt.pos[s] += acc + 1
                self.metrics.record_speculation(tier, nd, acc)
                # per-token ground-truth agreement for the draft tier's
                # gate: every verified draft up to (and including) the
                # first rejection — past it the drafts' context is
                # already wrong, so the comparison stops being oracle
                for j in range(min(acc + 1, nd)):
                    self.metrics.calibration.record_verify_outcome(
                        tier - 1, float(req.draft_confs[j]), j < acc)
                req.draft_tokens = []
                req.draft_confs = []
            else:
                req.emit(int(tok[s]), float(conf[s]), t_emit)
                rt.tok[s] = tok[s]
        for s in plan.decode_rows:
            if s not in ver:
                rt.pos[s] += 1
        if use_spec and drafting:
            # stage the fetched drafts on their target requests (consumed
            # by the next tier's verify pass later this same tick),
            # truncated at the first token the calibrated gate distrusts
            thr = (self.spec_delta if self.spec_delta is not None
                   else self.scheduler.delta(tier))
            for s in drafting:
                req = rt.draft_req[s]
                dl = int(plan.draft_len[s])
                keep = 0
                for j in range(dl):
                    if float(dconf[s, j]) < thr:
                        break
                    keep += 1
                req.draft_tokens = [int(x) for x in dtok[s, :keep]]
                req.draft_confs = [float(x) for x in dconf[s, :keep]]
        return len(plan.decode_rows)

    def _exec_split(self, tier: int, rt: _TierRuntime,
                    plan: StepPlan, now: float) -> int:
        """Legacy split execution (the ``use_unified_step=False`` escape
        hatch, and the only backend for dense-arena / recurrent-state
        tiers): launch the prefill chunk batch, launch the fused decode
        step — rows whose final chunk completed decode in the same tick,
        their first token flowing into the decode input through a
        device-side ``where`` — then pay a single blocking host sync for
        both result pairs.  Two compiled programs on mixed ticks, which
        is exactly what the unified backend fuses away."""
        pf = None
        tr = self.tracer
        if plan.prefill_rows:
            t0 = tr.now_us() if tr is not None else 0.0
            try:
                with obs.annotation(f"run_chunk/{rt.spec.name}",
                                    self.profile_annotations):
                    tok, conf, cache = self._launch(
                        tier, "run_chunk",
                        lambda: rt.run_chunk(plan.tokens, plan.pos,
                                             plan.q_len))
            except _RetryExhausted as e:
                # fail one victim, re-plan, and restart the tick for the
                # survivors (the failed launch advanced no host state)
                self._fail_one(tier, rt,
                               plan.prefill_rows + plan.decode_rows, now, e)
                plan = self._build_plan(rt)
                if plan is None:
                    return 0
                return self._exec_split(tier, rt, plan, now)
            rt.pool.cache = cache
            if tr is not None:
                tr.phase("launch", tier, t0, tick=self.tick_id,
                         kind="chunk", width=plan.width)
            self.metrics.record_launches(tier, 1)
            self.metrics.record_prefill_tokens(plan.live_prefill_tokens,
                                               rt.capacity * plan.width)
            self.metrics.record_step_tokens(tier, plan.live_prefill_tokens,
                                            rt.capacity * plan.width)
            for s in plan.prefill_rows:
                rt.prefill_pos[s] += int(plan.q_len[s])
                if rt.prefix:
                    rt.pool.publish_prefix(s, rt.slot_req[s].prompt,
                                           int(rt.prefill_pos[s]))
            t_dec = self.clock.now()
            for s in plan.finishing:
                req = rt.slot_req[s]
                req.start_decode(t_dec)
                self._trace_req(req, "DECODE", tier, int(plan.shard[s]))
                rt.pos[s] = req.prompt_tokens   # next decode writes here
            pf = {"tok": tok, "conf": conf, "finished": plan.finishing}
        dc = self._decode_launch(tier, rt, pf, now)
        emit_first = pf is not None and pf["finished"]
        if not emit_first and dc is None:
            return 0
        fetched = self._fetch(tier, (
            (pf["tok"], pf["conf"]) if emit_first else None,
            (dc["tok"], dc["conf"]) if dc is not None else None))
        t_emit = self.clock.now()       # post-compute (see _admit)
        if emit_first:
            ptok, pconf = fetched[0]
            for s in pf["finished"]:
                req = rt.slot_req[s]
                if req is None:
                    continue    # failed mid-tick (decode retry exhaustion)
                req.emit(int(ptok[s]), float(pconf[s]), t_emit)
                rt.tok[s] = ptok[s]
        if dc is None:
            return 0
        ntok, nconf = fetched[1]
        for slot in dc["active"]:
            req = rt.slot_req[slot]
            req.emit(int(ntok[slot]), float(nconf[slot]), t_emit)
            rt.tok[slot] = ntok[slot]
            rt.pos[slot] += 1
        return len(dc["active"])

    def _decode_launch(self, tier: int, rt: _TierRuntime,
                       pf: Optional[dict], now: float) -> Optional[dict]:
        """Launch half of the split backend's fused decode step.  Rows
        whose final prefill chunk completed this tick decode in the same
        tick; their first token is still on device (in ``pf``), so it is
        mixed into the decode input with a device-side ``where`` instead
        of a host round-trip."""
        decoding = rt.decoding()
        if pf is not None and pf["finished"]:
            # rows whose first token is still on device look one emit
            # behind `decode_finished`: drop those the pending prefill
            # emit already completes (gen_len=1), exactly as the old
            # commit-then-decode order did
            decoding = [s for s in decoding
                        if s not in pf["finished"]
                        or len(rt.slot_req[s].tokens) + 1
                        < rt.slot_req[s].gen_len]
        if not decoding:
            return None
        if rt.paged:
            # grow page tables lazily as rows cross block boundaries,
            # oldest row (per data shard) first.  A row denied a block
            # *stalls*: its page stays unmapped (writes hit the null
            # block), its output is discarded, and it retries next tick —
            # attention KV replay is idempotent, and over-subscription is
            # rejected at engine construction for models with recurrent
            # state.
            dec = set(decoding)
            active = [s for s in rt.pool.bound_rows()
                      if s in dec and rt.pool.ensure_blocks(
                          s, int(rt.pos[s]))]
            if not active:
                return None
        else:
            active = decoding
        tok_in = rt.put_rows(rt.tok[:, None])
        if pf is not None and pf["finished"]:
            fresh = np.zeros(rt.capacity, bool)
            fresh[pf["finished"]] = True
            tok_in = jnp.where(rt.put_rows(fresh[:, None]),
                               pf["tok"][:, None].astype(jnp.int32), tok_in)
        # rows mid-prefill share the fused decode batch but must not touch
        # their (bound, partially-filled) pages: mask them to the null
        # block in the decode step's page-table copy
        tr = self.tracer
        while True:
            t0 = tr.now_us() if tr is not None else 0.0
            try:
                with obs.annotation(f"run_step/{rt.spec.name}",
                                    self.profile_annotations):
                    nxt, conf, cache = self._launch(
                        tier, "run_step",
                        lambda: rt.run_step(tok_in,
                                            mask_rows=rt.prefilling()))
            except _RetryExhausted as e:
                # fail one active row and relaunch for the rest: the
                # victim's page-table row is already unmapped, so its
                # residual token in tok_in scatters to the null block
                victim = self._fail_one(tier, rt, active, now, e)
                active = [s for s in active if s != victim]
                if not active:
                    return None
                continue
            rt.pool.cache = cache
            break
        if tr is not None:
            tr.phase("launch", tier, t0, tick=self.tick_id, kind="decode",
                     width=1)
        self.metrics.record_launches(tier, 1)
        self.metrics.record_step_tokens(tier, len(active), rt.capacity)
        return {"active": active, "tok": nxt, "conf": conf}

    def _finish(self, tier: int, now: float) -> None:
        """Gate finished rows, traced as the tick's ``finish`` phase;
        completed *escalated* requests additionally stream their
        escalation outcomes (did the tiers' answers agree?) into the
        calibration telemetry."""
        tr = self.tracer
        if tr is None:
            self._finish_requests(tier, now)
            return
        t0 = tr.now_us()
        done, esc = self._finish_requests(tier, now)
        tr.phase("finish", tier, t0, tick=self.tick_id,
                 completed=done, escalated=esc)

    def _finish_requests(self, tier: int, now: float):
        rt = self.runtimes[tier]
        last = tier == len(self.tiers) - 1
        # fault injection: an escalation storm overrides this gate's
        # decisions for the tick (forced decisions still stream into the
        # gate stats and calibration telemetry like real ones)
        forced = (None if last or self.faults is None
                  else self.faults.force_escalation(self.tick_id, tier))
        done = esc = 0
        for slot in rt.occupied():
            req = rt.slot_req[slot]
            if not (req.state is RequestState.DECODE and req.decode_finished):
                continue
            seq_conf = req.gate(self.conf_reduce)
            if not last and self.scheduler.gate_decision(tier, seq_conf,
                                                         force=forced):
                req.escalate(now)
                self.scheduler.push_escalated(req)
                # span on the *next* tier's track: queued for escalation
                self._trace_req(req, "ESCALATED", tier + 1, None)
                esc += 1
                if self.speculation_k and rt.spec_draft and rt.ragged:
                    # speculative mode: keep this row alive as the
                    # request's draft row — its prompt KV is already
                    # resident, so the cheap tier can catch up on the
                    # expensive tier's emissions and draft ahead.  The
                    # row changes role, not owner: no pool/scheduler
                    # release (the slots invariant checker sees one
                    # binding throughout).
                    self._release_draft(req)    # M>2: drop the older row
                    rt.draft_req[slot] = req
                    rt.slot_req[slot] = None
                    rt.tok[slot] = 0
                    rt.pos[slot] = req.prompt_tokens  # rewind: replay the
                    rt.prefill_pos[slot] = 0          # target's emissions
                    req.draft_tier = tier
                    req.draft_slot = slot
                    continue
            else:
                # post-compute time: the final decode step belongs to this
                # request's latency (`now` was sampled at step start)
                req.complete(self.clock.now())
                self._release_draft(req)
                self.metrics.record_completion(req)
                if req.tier > 0:
                    # escalation outcome: the expensive tier's answer is
                    # in; stream agreement into the reliability bins
                    self.metrics.record_gate_outcomes(req)
                if self.tracer is not None:
                    self.tracer.request_done(
                        req.rid, tier,
                        rt.pool.shard_of(slot) if rt.paged else None,
                        tick=self.tick_id)
                done += 1
            rt.slot_req[slot] = None
            rt.tok[slot] = 0
            rt.pos[slot] = 0
            rt.prefill_pos[slot] = 0
            if rt.paged:
                rt.pool.release(slot)
            self.scheduler.release(tier, slot)
        return done, esc

    def step(self, now: Optional[float] = None) -> None:
        now = self.clock.now() if now is None else now
        self.tick_id += 1
        if self.faults is not None:
            self.faults.begin_tick(self.tick_id, self)
        # minimum observed tick duration: the unit of the shedding pass's
        # min-ticks service-time lower bound (constant dt under a
        # VirtualClock, so the floor is exact there)
        if self._last_tick_t is not None:
            d = now - self._last_tick_t
            if d > 0 and (self._min_tick_dt is None
                          or d < self._min_tick_dt):
                self._min_tick_dt = d
        self._last_tick_t = now
        tr = self.tracer
        tick_t0 = tr.now_us() if tr is not None else 0.0
        # open each tier's token-budget window: unified tiers pre-charge
        # the tick's carried decode+chunk load (one currency), split
        # tiers start the legacy prefill-only window at zero
        self._budget_used = [
            self._tick_load(rt) if rt.unified else 0
            for rt in self.runtimes]
        self._admitted = [0] * len(self.tiers)
        active = []
        # StepTraceAnnotation(step_num=tick_id): the join key between an
        # opt-in jax-profiler device trace and the host tracer's events
        with obs.step_annotation(self.tick_id, self.profile_annotations):
            for tier in range(len(self.tiers)):
                self._shed(tier, now)
                self._admit(tier, now)
                active.append(self._tier_step(tier, now))
                self._finish(tier, now)
            # Trailing admission pass: requests escalated this tick enter
            # the next tier's slots immediately (their decode starts next
            # tick), keeping the invariant `free slot => empty queue` at
            # tick ends.
            for tier in range(len(self.tiers)):
                self._admit(tier, now)
        if tr is not None:
            for t, rt in enumerate(self.runtimes):
                tr.counter(f"queue depth/{rt.spec.name}",
                           len(self.scheduler.queues[t]), tid=t)
                tr.counter(f"live rows/{rt.spec.name}",
                           len(rt.occupied()), tid=t)
            tr.phase("tick", len(self.tiers), tick_t0, tick=self.tick_id,
                     t_engine=now)
        self.metrics.record_step(active, now)
        self.metrics.sync_gate_stats(self.scheduler.gate_stats)

    # -- driver ------------------------------------------------------------

    def _any_occupied(self) -> bool:
        return any(rt.occupied() for rt in self.runtimes)

    def _done(self) -> bool:
        return self.scheduler.pending == 0 and not self._any_occupied()

    def memory_stats(self) -> List[dict]:
        """Per-tier KV arena accounting: block geometry, static arena
        bytes, high-water bytes actually mapped (paged, overall and per
        data shard), and what the dense one-page-per-request arena would
        have allocated."""
        return [dict(tier=rt.spec.name, **rt.pool.memory_stats())
                for rt in self.runtimes]

    def mesh_topology(self) -> List[dict]:
        """Per-tier mesh layout (None entries for unmeshed tiers): axis
        sizes, device count/ids, data shard count, and whether params are
        tensor-sharded — recorded into serving summaries and the BENCH
        json."""
        out = []
        for rt in self.runtimes:
            if rt.mesh is None:
                out.append({"tier": rt.spec.name, "mesh": None,
                            "devices": 1, "data_shards": 1})
                continue
            out.append({
                "tier": rt.spec.name,
                "mesh": {a: int(s) for a, s in
                         zip(rt.mesh.axis_names, rt.mesh.devices.shape)},
                "devices": int(rt.mesh.devices.size),
                "device_ids": [int(d.id) for d in rt.mesh.devices.flat],
                "data_shards": rt.data_shards,
                "shard_params": bool(rt.spec.shard_params),
            })
        return out

    def compile_stats(self) -> List[dict]:
        """Per-tier compiled-program accounting for the token-batch
        executors: the widths :meth:`warmup` compiled, the widths ticks
        actually launched, and any launched outside the warmed set — a
        mid-run recompile, which the bucketed ragged layout exists to
        eliminate (test-asserted)."""
        out = []
        for rt in self.runtimes:
            mid = sorted(rt.launched_widths - rt.warmed_widths) \
                if rt.warmed_widths else []
            out.append({
                "tier": rt.spec.name,
                "backend": ("ragged" if rt.ragged else
                            "unified" if rt.unified else
                            "split" if rt.chunked else "legacy"),
                "warmed_widths": sorted(rt.warmed_widths),
                "launched_widths": sorted(rt.launched_widths),
                "compiled_programs": len(rt.warmed_widths
                                         | rt.launched_widths),
                "mid_run_recompiles": mid,
            })
        return out

    def reset_clock(self) -> None:
        """Restart the clock at t=0.  Call after compilation / setup and
        before submitting timed requests, so arrival timestamps are
        relative to the start of serving rather than engine construction."""
        self.clock.reset()

    def warmup(self) -> None:
        """Trigger tier compiles before the clock starts: one prefill +
        one decode per tier on dummy data.  The decode's returned cache is
        rebound (step_fn donates its cache input on accelerators); the
        dummy write lands in the reserved null block (paged: empty page
        tables point at block 0) or at position 0 of free rows (dense),
        neither of which the next occupant ever attends.  Ends by
        resetting the clock so compile time never counts against request
        latency."""
        for rt in self.runtimes:
            if rt.ragged:
                # every bucket width of the one-per-tick ragged program
                # compiles here (q_len all zero: the dummy writes land in
                # the null block), so a mixed-length run never pays a
                # mid-run recompile — compile_stats() asserts this
                zr = np.zeros(rt.capacity, np.int32)
                for w in rt.flat_buckets:
                    z = np.zeros((1, w), np.int32)
                    if rt.spec_fn is not None:
                        out = rt.run_spec(z, z, zr, zr, zr)
                        rt.pool.cache = out[-1]
                    else:
                        _, _, rt.pool.cache = rt.run_ragged(z, z, zr, zr)
                rt.warmed_widths = set(rt.flat_buckets)
                rt.launched_widths = set()
                continue
            if rt.unified:
                # both compiled widths of the padded one-per-tick
                # program: the mixed token batch (any prefill row live)
                # and the width-1 decode-only batch
                for w in dict.fromkeys((rt.chunk, 1)):
                    z = np.zeros((rt.capacity, w), np.int32)
                    _, _, rt.pool.cache = rt.run_mixed(
                        z, z, np.zeros(rt.capacity, np.int32))
                rt.warmed_widths = set(dict.fromkeys((rt.chunk, 1)))
                rt.launched_widths = set()
                continue
            if rt.chunked:
                ztok = np.zeros((rt.capacity, rt.chunk), np.int32)
                _, _, rt.pool.cache = rt.run_chunk(
                    ztok, ztok, np.zeros(rt.capacity, np.int32))
            else:
                prompts = np.zeros((rt.capacity, self.prompt_len), np.int32)
                rt.run_prefill(prompts)
            zeros = np.zeros((rt.capacity, 1), np.int32)
            with rt._ctx():
                _, _, rt.pool.cache = rt.step_fn(
                    rt.params, rt.put_rows(zeros), rt.pool.cache,
                    rt.put_rows(zeros), rt.page_table_device())
        self.reset_clock()

    def run(self, max_steps: int = 1_000_000, *,
            metrics_interval: Optional[float] = None,
            on_snapshot=None) -> dict:
        """Drive to completion; returns ``metrics.summary()``.

        ``metrics_interval`` emits a :meth:`ServingMetrics.snapshot`
        dict to ``on_snapshot`` every that-many clock units (seconds, or
        ticks under a VirtualClock) — the streaming view of escalation
        rate, per-gate ECE, and agreement the ``--metrics-interval``
        CLI flag prints as one line per window."""
        steps = 0
        next_snap = (self.clock.now() + metrics_interval
                     if metrics_interval else None)
        while not self._done():
            now = self.clock.now()
            if not self._any_occupied() and not any(
                    self.scheduler.admissible(t, now)
                    for t in range(len(self.tiers))):
                # idle: jump/sleep to the arrival of the queue *head* —
                # admission is FIFO, so the head is what unblocks the queue
                # (min over all arrivals can sit before the head's time and
                # would spin a VirtualClock forever on out-of-order submits)
                nxt = self.scheduler.queues[0][0].arrival_time
                self.clock.wait_until(nxt)
                continue
            self.step(self.clock.now())
            self.clock.step_done()
            steps += 1
            if next_snap is not None and self.clock.now() >= next_snap:
                if on_snapshot is not None:
                    on_snapshot(self.metrics.snapshot(self.clock.now()))
                next_snap = self.clock.now() + metrics_interval
            if steps > max_steps:
                raise RuntimeError(
                    f"engine did not drain after {steps} steps (scheduler "
                    "stuck?): " + self._drain_diagnostics())
        return self.metrics.summary()
