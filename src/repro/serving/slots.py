"""Paged KV-cache slot pools.

Each cascade tier owns a fixed arena of ``capacity`` cache rows (one page
per in-flight request) allocated once via :func:`repro.models.init_cache`
at ``[capacity, max_seq, ...]``.  A free-list allocator hands out row ids;
freeing a slot returns the row for reuse without touching device memory —
the next occupant's prefill overwrites the prefix ``[0, P)`` and decode
masks positions ``> pos`` per row, so stale keys from the previous
occupant are never attended to.

Recurrent state (mamba conv/ssm, rwkv6) has no sequence dim per row and is
fully overwritten at prefill, so reuse is trivially safe there too.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models import cache as cache_lib


class SlotAllocator:
    """Fixed-capacity free-list allocator."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._used = set()

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._used.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        self._free.append(slot)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._used)

    @property
    def utilization(self) -> float:
        return self.num_used / self.capacity


def _batch_axes(cfg, capacity: int, max_seq: int):
    """Pytree (matching the cache) of each leaf's batch-dim index —
    period-stacked leaves carry a leading ``num_periods`` dim, so their
    batch axis is 1, not 0."""
    decl = cache_lib.declare_cache(cfg, capacity, max_seq)
    return jax.tree.map(lambda c: c.axes.index("batch"), decl,
                        is_leaf=lambda x: isinstance(x, cache_lib.CP))


def _write_rows(full, part, bax: int, slot_ids):
    """Scatter `part`'s rows into `full` at `slot_ids` along axis `bax`,
    writing only the prefix of any dim where part is shorter (the KV seq
    dim after a prefill of P < max_seq tokens)."""
    idx = [slice(None)] * full.ndim
    idx[bax] = slot_ids
    for d in range(full.ndim):
        if d != bax and full.shape[d] != part.shape[d]:
            idx[d] = slice(0, part.shape[d])
    return full.at[tuple(idx)].set(part.astype(full.dtype))


def _take_rows(tree, bax_tree, n: int):
    return jax.tree.map(
        lambda a, bax: jax.lax.slice_in_dim(a, 0, n, axis=bax),
        tree, bax_tree)


class TierSlotPool:
    """Slot allocator + the tier's actual cache arena."""

    def __init__(self, cfg, capacity: int, max_seq: int, dtype=jnp.float32):
        self.cfg = cfg
        self.capacity = capacity
        self.max_seq = max_seq
        self.allocator = SlotAllocator(capacity)
        self.cache = cache_lib.init_cache(cfg, capacity, max_seq, dtype)
        self._bax = _batch_axes(cfg, capacity, max_seq)

    def write_prefill(self, slot_ids: Sequence[int], part_cache) -> None:
        """Write a packed prefill cache (rows ``0..n-1``) into arena rows
        ``slot_ids``."""
        n = len(slot_ids)
        ids = jnp.asarray(slot_ids, jnp.int32)
        part = _take_rows(part_cache, self._bax, n)
        self.cache = jax.tree.map(
            lambda full, p, bax: _write_rows(full, p, bax, ids),
            self.cache, part, self._bax)
