"""Block-paged KV-cache slot pools.

Each cascade tier owns

  * ``capacity`` request rows — the fused decode batch.  Recurrent state
    (mamba conv/ssm, rwkv6, rwkv_cmix token shift) lives per row and is
    fully overwritten at prefill, so row reuse is trivially safe.
  * a shared pool of ``num_blocks`` fixed-size KV blocks
    (``[num_blocks, block_size, kv_heads, head_dim]`` per attention
    layer, from :func:`repro.models.cache.init_paged_cache`).  Each row
    maps its live tokens through a page table ``[capacity, pages_per_row]``
    of block ids; entries default to the reserved **null block 0**, which
    is never allocated — unmapped pages (and rows stalled waiting for a
    block) read/write block 0 and are masked or discarded.

Freeing returns blocks to the free list without touching device memory.
Reuse is safe because a block only becomes reachable through a row's page
table when that row's position enters the page, and decode masks key
positions ``> pos`` per row — by the time any position of a reused block
is attended, the new occupant has overwritten it (prefill scatters the
prompt prefix; decode writes token ``pos`` before reading it).

Deadlock freedom under over-subscription (``num_blocks`` smaller than
``capacity * pages_per_row + 1``) follows an oldest-first discipline:
the oldest bound row may always take a free block, while younger rows
and new admissions must leave ``worst_remaining(oldest)`` blocks free.
Since every row releases all its blocks when it finishes, the oldest row
always completes, then the next-oldest inherits the guarantee.

**Refcounted prefix sharing** (``TierSlotPool(prefix_chunk=...)``): every
mapping of a block — a row's page-table entry or a prefix-index entry —
holds one reference; :meth:`BlockAllocator.free` decrements and a block
returns to the free list only at refcount 0.  The per-shard prefix index
is a hash map keyed by the exact token bytes of chunk-aligned prompt
prefixes (boundaries are chunk multiples rounded **down** to a block
boundary, so a published block is full and never written again — the
publisher's next scatter starts at or past the boundary).  Admission
matches the longest indexed prefix, maps those blocks read-only into the
new row's page table (pinning them with a refcount), and chunked prefill
resumes at the first uncached token.  Any write past a shared boundary
lands in a fresh private block; if an index entry's boundary splits a
block (possible only for entries not produced by the aligned publisher,
e.g. hand-built ones), :meth:`TierSlotPool.bind` copies that block on
write into a private page before any scatter.  Eviction is
refcount-aware LRU over index entries: only blocks whose every reference
is an index reference can return to the free list, so releasing a
preempted victim never reclaims blocks still shared with the index or
other rows.

**Sharded pools** (multi-device serving): when a tier runs on a mesh
with ``D`` data shards, its ``capacity`` rows and its block pool are
partitioned into ``D`` contiguous ranges — shard ``d`` owns rows
``[d*capacity/D, (d+1)*capacity/D)`` and blocks
``[d*num_blocks/D, (d+1)*num_blocks/D)``, matching the device layout of
the row- and ``kv_blocks``-sharded cache arrays
(:func:`repro.models.cache.cache_spec_leaf`), so a request's KV blocks
live on the data shard that decodes its row.  Allocation, admission
accounting, and the oldest-first reserve discipline all become
per-shard: each shard's oldest row can always grow, so each shard is
independently deadlock-free.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.models import cache as cache_lib
from repro.models.sharding import data_axis_size

NULL_BLOCK = 0


class SlotAllocator:
    """Fixed-capacity free-list allocator over request rows.

    ``shards > 1`` partitions the rows into contiguous per-shard ranges
    (``capacity`` must divide evenly); ``alloc(shard)`` then pops from
    that shard's free list only, and ``alloc(None)`` balances by picking
    the shard with the most free rows (lowest shard id on ties).  With
    the default ``shards=1`` behaviour is identical to the unsharded
    allocator (LIFO free list, ascending first pass).
    """

    def __init__(self, capacity: int, shards: int = 1):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        if shards <= 0 or capacity % shards:
            raise ValueError(
                f"capacity {capacity} must divide into {shards} shards")
        self.capacity = capacity
        self.shards = shards
        self._span = capacity // shards
        self._free: List[List[int]] = [
            list(range((s + 1) * self._span - 1, s * self._span - 1, -1))
            for s in range(shards)]
        self._used = set()

    def shard_of(self, slot: int) -> int:
        return slot // self._span

    def alloc(self, shard: Optional[int] = None) -> Optional[int]:
        if shard is None:
            shard = max(range(self.shards),
                        key=lambda s: (len(self._free[s]), -s))
        if not self._free[shard]:
            return None
        slot = self._free[shard].pop()
        self._used.add(slot)
        return slot

    def free(self, slot: int) -> None:
        # double-free guard: a slot id outside the used set (already
        # freed, or never allocated) must raise — silently re-appending
        # it would hand the same row to two requests
        if slot not in self._used:
            raise ValueError(
                f"slot {slot} is not allocated (double free?)")
        self._used.remove(slot)
        self._free[self.shard_of(slot)].append(slot)

    def free_in(self, shard: Optional[int]) -> int:
        if shard is None:
            return self.num_free
        return len(self._free[shard])

    @property
    def num_free(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def num_used(self) -> int:
        return len(self._used)

    @property
    def utilization(self) -> float:
        return self.num_used / self.capacity


class BlockAllocator:
    """Free-list over KV blocks ``1..num_blocks-1`` (0 = null block).

    ``shards > 1`` partitions the block ids into contiguous per-shard
    ranges aligned with the ``kv_blocks``-sharded device arrays
    (``num_blocks`` must divide evenly); shard 0's range contains the
    reserved null block, so it exposes one fewer usable block.
    ``alloc(shard)`` pops from that shard's free list; per-shard
    high-water marks feed the BENCH json's per-shard KV accounting.

    Blocks are **refcounted** for prefix sharing: ``alloc`` hands out a
    block at refcount 1, :meth:`ref` adds a reference (an extra row
    page-table mapping or a prefix-index entry), and :meth:`free`
    decrements — the block rejoins the free list only when the count
    reaches 0.  A block is therefore in exactly one of three states:
    free (on a shard free list), withheld (:meth:`reserve`), or live
    (refcount >= 1); ``shared_high_water`` tracks the peak number of
    blocks with refcount >= 2.
    """

    def __init__(self, num_blocks: int, shards: int = 1):
        if num_blocks < 2:
            raise ValueError("need at least one block besides the null block")
        if shards <= 0 or num_blocks % shards:
            raise ValueError(
                f"num_blocks {num_blocks} must divide into {shards} shards")
        self.num_blocks = num_blocks
        self.shards = shards
        self._span = num_blocks // shards
        # shard s owns ids [s*span, (s+1)*span); descending lists pop the
        # lowest id first; the null block (id 0, shard 0) is never free
        self._free: List[List[int]] = [
            list(range((s + 1) * self._span - 1,
                       max(s * self._span - 1, 0), -1))
            for s in range(shards)]
        self._used = set()
        self._used_by_shard = [0] * shards
        self._refcount = {}             # live block -> refs (>= 1)
        self._shared = 0                # live blocks with refcount >= 2
        # blocks withheld from the free lists by fault injection
        # (reserve()/restore()) — never allocated, never in _used
        self._reserved: List[List[int]] = [[] for _ in range(shards)]
        self.high_water = 0
        self.high_water_by_shard = [0] * shards
        self.shared_high_water = 0

    def shard_of(self, block: int) -> int:
        return block // self._span

    def alloc(self, shard: int = 0) -> Optional[int]:
        if not self._free[shard]:
            return None
        b = self._free[shard].pop()
        self._used.add(b)
        self._used_by_shard[shard] += 1
        self._refcount[b] = 1
        self.high_water = max(self.high_water, len(self._used))
        self.high_water_by_shard[shard] = max(
            self.high_water_by_shard[shard], self._used_by_shard[shard])
        return b

    def ref(self, block: int) -> None:
        """Add a reference to a live block (an extra page-table mapping
        or a prefix-index entry).  Sharing a block that is not currently
        allocated raises — a free or withheld block's contents are about
        to be overwritten by the next occupant."""
        if block not in self._used:
            raise ValueError(
                f"block {block} is not allocated (cannot share it)")
        rc = self._refcount[block] + 1
        self._refcount[block] = rc
        if rc == 2:
            self._shared += 1
            self.shared_high_water = max(self.shared_high_water,
                                         self._shared)

    def refcount(self, block: int) -> int:
        """Current reference count (0 for free/withheld/null blocks)."""
        return self._refcount.get(block, 0)

    def free(self, block: int) -> None:
        # double-free guard: a block id outside the used set (already
        # freed, reserved, the null block, or never allocated) must
        # raise — silently re-appending it would map one KV block into
        # two rows' page tables
        if block not in self._used:
            raise ValueError(
                f"block {block} is not allocated (double free?)")
        rc = self._refcount[block] - 1
        if rc > 0:
            # still shared: drop one reference, keep the block live
            self._refcount[block] = rc
            if rc == 1:
                self._shared -= 1
            return
        del self._refcount[block]
        self._used.remove(block)
        shard = self.shard_of(block)
        self._used_by_shard[shard] -= 1
        self._free[shard].append(block)

    def used_in(self, shard: int) -> int:
        return self._used_by_shard[shard]

    @property
    def num_shared(self) -> int:
        """Live blocks currently referenced more than once."""
        return self._shared

    def reserve(self, n: int, shard: int = 0) -> int:
        """Withhold up to `n` free blocks on `shard` (fault injection:
        mid-run pool shrinkage).  Withheld blocks leave the free list but
        are not marked used; :meth:`restore` returns them.  Returns the
        number actually withheld."""
        take = min(int(n), len(self._free[shard]))
        for _ in range(take):
            self._reserved[shard].append(self._free[shard].pop())
        return take

    def restore(self, shard: Optional[int] = None) -> int:
        """Return withheld blocks to their free lists (all shards by
        default).  Returns the number restored."""
        shards = range(self.shards) if shard is None else (shard,)
        restored = 0
        for s in shards:
            restored += len(self._reserved[s])
            self._free[s].extend(self._reserved[s])
            self._reserved[s] = []
        return restored

    def reserved_in(self, shard: int) -> int:
        return len(self._reserved[shard])

    def free_in(self, shard: int) -> int:
        return len(self._free[shard])

    @property
    def num_free(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def num_used(self) -> int:
        return len(self._used)


class PrefixEntry:
    """One cached prompt prefix: ``ntokens`` block-aligned tokens whose
    KV lives in ``blocks`` (all on one shard).  The entry holds one
    allocator reference per listed block; ``last_use`` orders LRU
    eviction."""

    __slots__ = ("ntokens", "blocks", "last_use")

    def __init__(self, ntokens: int, blocks: List[int], last_use: int):
        self.ntokens = ntokens
        self.blocks = blocks
        self.last_use = last_use


# -- pytree scatter helpers --------------------------------------------------


def _leaf_meta(decl_tree):
    """Per-leaf scatter metadata from a paged cache declaration: either
    ('paged', i) with i the kv_blocks axis (offset axis is i+1), or
    ('row', i) with i the per-request batch axis."""
    def meta(c: cache_lib.CP):
        if "kv_blocks" in c.axes:
            return ("paged", c.axes.index("kv_blocks"))
        return ("row", c.axes.index("batch"))
    return jax.tree.map(meta, decl_tree,
                        is_leaf=lambda x: isinstance(x, cache_lib.CP))


def _write_rows(full, part, bax: int, slot_ids):
    """Scatter `part`'s rows into `full` at `slot_ids` along axis `bax`,
    writing only the prefix of any dim where part is shorter."""
    idx = [slice(None)] * full.ndim
    idx[bax] = slot_ids
    for d in range(full.ndim):
        if d != bax and full.shape[d] != part.shape[d]:
            idx[d] = slice(0, part.shape[d])
    return full.at[tuple(idx)].set(part.astype(full.dtype))


def _write_paged(full, part, bax: int, blk, off):
    """Scatter packed prefill tokens into the block pool.  ``full`` has
    (kv_blocks, block) at axes (bax, bax+1); ``part`` is the dense prefill
    leaf with (batch, seq) there; ``blk``/``off`` are [n, prompt_len]
    index arrays.  Adjacent advanced indices keep their position, so the
    gather/scatter dims line up with part's (batch, seq) dims."""
    idx = [slice(None)] * full.ndim
    idx[bax] = blk
    idx[bax + 1] = off
    pidx = [slice(None)] * part.ndim
    pidx[bax] = slice(0, blk.shape[0])
    pidx[bax + 1] = slice(0, blk.shape[1])
    return full.at[tuple(idx)].set(part[tuple(pidx)].astype(full.dtype))


class TierSlotPool:
    """Request rows + block-paged KV arena for one cascade tier.

    ``num_blocks=None`` fully provisions the pool
    (``capacity * ceil(max_seq / block_size) + 1`` blocks): identical
    admission behaviour to the old one-page-per-request arena, and no
    stall can ever occur.  Smaller ``num_blocks`` over-subscribes the
    arena — admission and block growth then enforce the oldest-first
    reserve discipline (see module docstring).

    ``mesh`` shards the pool for multi-device serving: request rows and
    KV blocks partition into ``data_axis_size(mesh)`` contiguous shards
    (``capacity`` must divide; ``num_blocks`` is rounded up to divide),
    the device arrays are placed with the matching NamedShardings
    (``kv_blocks``/``batch`` over the data axes, kv heads over 'model' —
    :func:`repro.models.cache.paged_cache_specs`), and allocation /
    reserve accounting run per shard so a row's blocks stay on its data
    shard.  ``data_shards`` overrides the shard count without a mesh
    (host-side accounting only; unit tests).
    """

    def __init__(self, cfg, capacity: int, max_seq: int, dtype=jnp.float32,
                 *, block_size: int = 16, num_blocks: Optional[int] = None,
                 mesh=None, data_shards: Optional[int] = None,
                 prefix_chunk: Optional[int] = None):
        if block_size <= 0:
            raise ValueError("block_size must be positive")
        if prefix_chunk is not None and prefix_chunk <= 0:
            raise ValueError("prefix_chunk must be positive")
        self.cfg = cfg
        self.capacity = capacity
        self.max_seq = max_seq
        self.dtype = dtype
        self.block_size = block_size
        self.mesh = mesh
        self.data_shards = (data_axis_size(mesh) if data_shards is None
                            else int(data_shards))
        if self.data_shards <= 0 or capacity % self.data_shards:
            raise ValueError(
                f"capacity {capacity} must divide into {self.data_shards} "
                "data shards (rows are partitioned across the mesh)")
        self._row_span = capacity // self.data_shards
        self.pages_per_row = math.ceil(max_seq / block_size)
        full = capacity * self.pages_per_row + 1
        self.num_blocks = full if num_blocks is None else int(num_blocks)
        if self.data_shards > 1:
            # round up so the block pool shards evenly over the data axis
            self.num_blocks = self.data_shards * math.ceil(
                self.num_blocks / self.data_shards)
            if self.num_blocks // self.data_shards < self.pages_per_row + 1:
                raise ValueError(
                    f"num_blocks={self.num_blocks} over {self.data_shards} "
                    f"shards cannot hold one full request per shard "
                    f"({self.pages_per_row} blocks + the null block)")
        elif self.num_blocks < self.pages_per_row + 1:
            raise ValueError(
                f"num_blocks={self.num_blocks} cannot hold one full request "
                f"({self.pages_per_row} blocks) plus the null block")
        self.oversubscribed = self.num_blocks < full
        self.blocks = BlockAllocator(self.num_blocks, self.data_shards)
        self.cache = cache_lib.init_paged_cache(
            cfg, capacity, self.num_blocks, block_size, dtype)
        decl = cache_lib.declare_paged_cache(
            cfg, capacity, self.num_blocks, block_size, dtype)
        if mesh is not None:
            specs = cache_lib.paged_cache_specs(
                cfg, capacity, self.num_blocks, block_size, mesh, dtype)
            shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda x: isinstance(x, PartitionSpec))
            self.cache = jax.device_put(self.cache, shardings)
        self._meta = _leaf_meta(decl)
        self.page_table = np.zeros((capacity, self.pages_per_row), np.int32)
        self._row_blocks: List[List[int]] = [[] for _ in range(capacity)]
        self._row_demand: List[int] = [self.pages_per_row] * capacity
        self._order: List[int] = []     # bound rows, oldest first
        # -- prefix cache state (inert when prefix_chunk is None) -------
        self.prefix_chunk = prefix_chunk
        self._index: List[dict] = [dict() for _ in range(self.data_shards)]
        self._index_refs: dict = {}     # block -> index references held
        self._lru = 0                   # monotonic LRU clock
        self._row_shared: List[int] = [0] * capacity   # read-only pages
        self._row_published: List[int] = [0] * capacity  # chunks published
        self._released_shared: dict = {}  # slot -> live blocks at release
        self.prefix_evictions = 0
        self.prefix_cow_copies = 0

    # -- admission-side block accounting -----------------------------------

    def shard_of(self, slot: int) -> int:
        """The data shard owning request row `slot` (contiguous ranges)."""
        return slot // self._row_span

    def shard_of_block(self, block: int) -> int:
        """The data shard owning KV block id `block`."""
        return self.blocks.shard_of(block)

    def _worst_remaining(self, slot: int) -> int:
        """Blocks `slot` may still need: its bound lifetime demand (from
        ``bind``'s row_tokens — mixed-length rows demand fewer pages than
        ``pages_per_row``) minus what it already holds."""
        return self._row_demand[slot] - len(self._row_blocks[slot])

    def _oldest_in(self, shard: int) -> Optional[int]:
        """Oldest bound row on `shard` (block-growth priority holder)."""
        for s in self._order:
            if self.shard_of(s) == shard:
                return s
        return None

    def _oldest_worst(self, shard: int = 0) -> int:
        oldest = self._oldest_in(shard)
        return self._worst_remaining(oldest) if oldest is not None else 0

    def blocks_for(self, ntokens: int) -> int:
        return math.ceil(ntokens / self.block_size)

    # -- prefix index (refcounted block sharing) ----------------------------

    @property
    def prefix_enabled(self) -> bool:
        return self.prefix_chunk is not None

    def _prefix_key(self, prompt, ntokens: int) -> bytes:
        """Index key for the first `ntokens` of `prompt`: the exact token
        bytes (a hash map keyed by content — no collision handling
        needed, unlike a lossy hash chain)."""
        return np.ascontiguousarray(
            np.asarray(prompt[:ntokens]), dtype=np.int32).tobytes()

    def _prefix_boundaries(self, limit: int) -> List[int]:
        """Publishable prefix boundaries <= `limit`, ascending: chunk
        multiples rounded down to a block boundary, so every block under
        a boundary is full and append-frozen by the time it is shared."""
        out = []
        k, chunk, bs = 1, self.prefix_chunk, self.block_size
        while k * chunk <= limit:
            b = (k * chunk // bs) * bs
            if b > 0 and (not out or b > out[-1]):
                out.append(b)
            k += 1
        return out

    def match_prefix(self, prompt, shard: int):
        """Longest indexed prefix of `prompt` on `shard`, as
        ``(ntokens, blocks)`` — ``(0, [])`` on a miss.  The match is
        capped at ``len(prompt) - 1`` tokens so at least one prompt
        token is always prefilled (the final chunk computes the
        first-token logits).  Touches the entry's LRU stamp; the caller
        must :meth:`bind` with the match before anything else allocates
        on this shard (eviction could otherwise reclaim the blocks)."""
        if self.prefix_chunk is None or len(prompt) < 2:
            return 0, []
        idx = self._index[shard]
        for b in reversed(self._prefix_boundaries(len(prompt) - 1)):
            ent = idx.get(self._prefix_key(prompt, b))
            if ent is not None:
                self._lru += 1
                ent.last_use = self._lru
                return ent.ntokens, list(ent.blocks)
        return 0, []

    def publish_prefix(self, slot: int, prompt, upto: int) -> int:
        """Insert `slot`'s completed chunk boundaries (prompt KV written
        for ``[0, upto)``) into its shard's prefix index, taking one
        block reference per listed block.  Re-publishing an existing key
        only refreshes its LRU stamp.  Returns entries added."""
        if self.prefix_chunk is None:
            return 0
        upto = min(int(upto), len(prompt))
        idx = self._index[self.shard_of(slot)]
        chunk, bs = self.prefix_chunk, self.block_size
        added, k = 0, self._row_published[slot] + 1
        while k * chunk <= upto:
            b = (k * chunk // bs) * bs
            if b > 0:
                key = self._prefix_key(prompt, b)
                self._lru += 1
                ent = idx.get(key)
                if ent is None:
                    blocks = [int(self.page_table[slot, j])
                              for j in range(b // bs)]
                    for blk in blocks:
                        self.blocks.ref(blk)
                        self._index_refs[blk] = \
                            self._index_refs.get(blk, 0) + 1
                    idx[key] = PrefixEntry(b, blocks, self._lru)
                    added += 1
                else:
                    ent.last_use = self._lru
            k += 1
        self._row_published[slot] = k - 1
        return added

    def _evict_entry(self, shard: int, key: bytes) -> None:
        ent = self._index[shard].pop(key)
        for b in ent.blocks:
            n = self._index_refs[b] - 1
            if n:
                self._index_refs[b] = n
            else:
                del self._index_refs[b]
            self.blocks.free(b)
        self.prefix_evictions += 1

    def _reclaim(self, shard: int, need_free: int) -> bool:
        """Evict LRU prefix entries on `shard` until its free list holds
        `need_free` blocks.  Only blocks whose every reference is an
        index reference actually return to the free list — blocks shared
        with live rows (or longer entries) just drop one reference."""
        idx = self._index[shard]
        while idx and self.blocks.free_in(shard) < need_free:
            key = min(idx, key=lambda kk: idx[kk].last_use)
            self._evict_entry(shard, key)
        return self.blocks.free_in(shard) >= need_free

    def evictable_in(self, shard: int) -> int:
        """Blocks on `shard` that dropping the whole prefix index would
        return to the free list (every reference is an index
        reference)."""
        if self.prefix_chunk is None:
            return 0
        seen, n = set(), 0
        for ent in self._index[shard].values():
            for b in ent.blocks:
                if b not in seen:
                    seen.add(b)
                    if self.blocks.refcount(b) == self._index_refs.get(b, 0):
                        n += 1
        return n

    def prefix_index_entries(self, shard: Optional[int] = None) -> int:
        if shard is not None:
            return len(self._index[shard])
        return sum(len(i) for i in self._index)

    def _alloc_reclaiming(self, shard: int) -> Optional[int]:
        b = self.blocks.alloc(shard)
        if b is None and self._reclaim(shard, 1):
            b = self.blocks.alloc(shard)
        return b

    def can_admit(self, prompt_len: int, shard: int = 0, *,
                  cached: int = 0, prefix_blocks: Sequence[int] = ()) -> bool:
        """True if a new request's pages for its first ``prompt_len``
        tokens fit on `shard` while leaving that shard's oldest bound
        row its worst-case remaining demand.  With a prefix match,
        `cached` tokens are served by `prefix_blocks` (only the suffix
        pages need fresh blocks); LRU-evictable index blocks count
        toward availability, minus the matched blocks that admission
        would pin (they stop being evictable once a row maps them)."""
        need = self.blocks_for(prompt_len) - cached // self.block_size
        avail = self.blocks.free_in(shard) + self.evictable_in(shard)
        if cached:
            avail -= sum(
                1 for b in set(prefix_blocks[:cached // self.block_size])
                if self.blocks.refcount(b) == self._index_refs.get(b, 0) > 0)
        return avail - need >= self._oldest_worst(shard)

    def bind(self, slot: int, ntokens: int,
             row_tokens: Optional[int] = None,
             prefix: Optional[tuple] = None) -> None:
        """Claim `slot` (newest) and map pages for its first ``ntokens``
        (the whole prompt under one-shot prefill; the cached prefix plus
        the first uncached chunk under chunked prefill — later chunks
        grow via :meth:`ensure_blocks`).  Fresh blocks come from
        `slot`'s data shard.  ``row_tokens`` bounds the row's lifetime
        demand (``prompt_len + gen_len``; default ``max_seq``) for the
        oldest-first reserve accounting.  Callers must check
        :meth:`can_admit` first.

        ``prefix=(cached, blocks)`` (from :meth:`match_prefix`) maps the
        first ``cached // block_size`` blocks read-only into the page
        table, pinning each with a refcount before anything else can
        evict them.  If ``cached`` splits a block (an unaligned entry —
        the engine's publisher only emits block-aligned boundaries), the
        split block is **copied on write**: its contents go to a fresh
        private page so the row's own scatters never touch shared
        memory."""
        if self._row_blocks[slot]:
            raise ValueError(f"slot {slot} already bound")
        shard = self.shard_of(slot)
        cached, pblocks = (0, []) if prefix is None else prefix
        full_shared = cached // self.block_size
        need = self.blocks_for(ntokens) - full_shared
        demand = self.blocks_for(self.max_seq if row_tokens is None
                                 else min(row_tokens, self.max_seq))
        if demand < self.blocks_for(ntokens):
            raise ValueError(f"row_tokens={row_tokens} smaller than the "
                             f"{ntokens} tokens being bound")
        # pin the shared prefix first: once the row holds a reference,
        # reclaim below cannot evict the matched blocks from under us
        for j in range(full_shared):
            self.blocks.ref(pblocks[j])
            self._row_blocks[slot].append(pblocks[j])
            self.page_table[slot, j] = pblocks[j]
        self._row_shared[slot] = full_shared
        self._row_demand[slot] = demand
        self._row_published[slot] = 0
        self._order.append(slot)
        if self.blocks.free_in(shard) < need and \
                not self._reclaim(shard, need):
            # roll back the shared pins so the failed bind leaks nothing
            for b in self._row_blocks[slot]:
                self.blocks.free(b)
            self._row_blocks[slot] = []
            self._row_shared[slot] = 0
            self._row_demand[slot] = self.pages_per_row
            self.page_table[slot] = NULL_BLOCK
            self._order.remove(slot)
            raise RuntimeError("bind without can_admit: no free blocks")
        for j in range(full_shared, self.blocks_for(ntokens)):
            b = self.blocks.alloc(shard)
            self._row_blocks[slot].append(b)
            self.page_table[slot, j] = b
        if cached % self.block_size:
            # copy-on-write for the split block: the row resumes writing
            # mid-page, so it needs a private copy of the shared tokens
            self._copy_blocks([pblocks[full_shared]],
                              [int(self.page_table[slot, full_shared])])
            self.prefix_cow_copies += 1

    def shared_pages(self, slot: int) -> int:
        """Leading read-only (prefix-shared) pages mapped into `slot`."""
        return self._row_shared[slot]

    def ensure_blocks(self, slot: int, pos: int) -> bool:
        """Grow `slot`'s page table to cover token index `pos` with
        blocks from its data shard.  Returns False (row must stall this
        tick) if the reserve discipline denies the allocation; a shard's
        oldest bound row is never denied.  When the free list runs
        short, LRU prefix entries are evicted first — blocks whose only
        references are index references return to the free list."""
        page = pos // self.block_size
        if page >= self.pages_per_row:
            raise ValueError(f"pos {pos} beyond max_seq {self.max_seq}")
        shard = self.shard_of(slot)
        is_oldest = self._oldest_in(shard) == slot
        while len(self._row_blocks[slot]) <= page:
            if not is_oldest and \
                    self.blocks.free_in(shard) - 1 < self._oldest_worst(shard):
                if not self._reclaim(shard, self._oldest_worst(shard) + 1):
                    return False
            b = self._alloc_reclaiming(shard)
            if b is None:
                return False
            j = len(self._row_blocks[slot])
            self._row_blocks[slot].append(b)
            self.page_table[slot, j] = b
        return True

    def bound_rows(self) -> List[int]:
        """Bound request rows, oldest first (block-growth priority)."""
        return list(self._order)

    def release(self, slot: int) -> None:
        """Drop `slot`'s block references and unmap its pages.  A block
        rejoins the free list only when its refcount hits zero — blocks
        still referenced by the prefix index (or another row sharing the
        prefix) stay live, so releasing a preempted victim never
        reclaims memory out from under a reader.  Stale device memory is
        never attended: the pages are unreachable once the table row is
        zeroed, and the next occupant overwrites a reused block before
        its positions pass the per-row mask.

        Releasing an unbound slot raises (double-release guard: the
        engine's finish, preemption, and failure paths must each release
        a row exactly once).  The error distinguishes a plain double
        release from one whose earlier release left blocks live via
        shared references — on a preemption replay of a cache-hit row
        the latter means "the blocks are with the prefix index, not
        leaked", which needs no allocator surgery."""
        if slot not in self._order:
            still = self._released_shared.get(slot, 0)
            if still:
                raise ValueError(
                    f"slot {slot} is already released; {still} of its "
                    "blocks remain live via shared references (prefix "
                    "index or other rows) — still shared, not leaked, "
                    "so there is nothing left to release")
            raise ValueError(f"slot {slot} is not bound (double release?)")
        still_live = 0
        for b in self._row_blocks[slot]:
            self.blocks.free(b)
            if self.blocks.refcount(b) > 0:
                still_live += 1
        self._released_shared[slot] = still_live
        self._row_blocks[slot] = []
        self._row_demand[slot] = self.pages_per_row
        self._row_shared[slot] = 0
        self._row_published[slot] = 0
        self.page_table[slot] = NULL_BLOCK
        self._order.remove(slot)

    # -- fault injection: mid-run arena shrinkage ---------------------------

    def shrink(self, nblocks: int) -> int:
        """Withhold up to `nblocks` free blocks from the arena (fault
        injection: a mid-run capacity loss).  Two caps keep the run
        deadlock-free: each shard keeps at least ``pages_per_row`` usable
        blocks (the construction-time floor — one full request can always
        be served), and each shard's free list keeps the oldest bound
        row's worst-case remaining demand (the reserve invariant the
        oldest-first discipline maintains).  Returns the number actually
        withheld; :meth:`unshrink` restores them."""
        remaining = int(nblocks)
        took = 0
        for s in range(self.data_shards):
            if remaining <= 0:
                break
            usable = self.blocks._span - (1 if s == 0 else 0)
            floor_cap = (usable - self.pages_per_row
                         - self.blocks.reserved_in(s))
            reserve_cap = self.blocks.free_in(s) - self._oldest_worst(s)
            take = min(remaining, max(min(floor_cap, reserve_cap), 0))
            got = self.blocks.reserve(take, s)
            took += got
            remaining -= got
        return took

    def unshrink(self) -> int:
        """Restore every block withheld by :meth:`shrink`."""
        return self.blocks.restore()

    # -- device-side writes ------------------------------------------------

    def _copy_blocks(self, src: Sequence[int], dst: Sequence[int]) -> None:
        """Copy whole KV blocks ``src[i] -> dst[i]`` in every paged leaf
        (the copy-on-write primitive: a row taking over a partially
        shared block duplicates it before its first scatter)."""
        src_ids = jnp.asarray(src, jnp.int32)
        dst_ids = jnp.asarray(dst, jnp.int32)

        def cp(full, meta):
            kind, ax = meta
            if kind != "paged":
                return full
            gi = [slice(None)] * full.ndim
            gi[ax] = src_ids
            si = [slice(None)] * full.ndim
            si[ax] = dst_ids
            return full.at[tuple(si)].set(full[tuple(gi)])

        self.cache = jax.tree.map(cp, self.cache, self._meta)

    def write_prefill(self, slot_ids: Sequence[int], part_cache) -> None:
        """Scatter a packed prefill cache (rows ``0..n-1``) into the tier
        arena: attention KV goes through the page tables into the block
        pool, recurrent leaves into their request rows.  ``bind`` must
        have allocated each slot's prompt pages already."""
        n = len(slot_ids)
        ids = jnp.asarray(slot_ids, jnp.int32)
        # token index t of row i lives at (page_table[slot_i, t // bs],
        # t % bs); prompt_len comes from the part cache's kv_seq dim
        prompt_len = _prompt_len(part_cache, self._meta)
        if prompt_len is not None:
            t = np.arange(prompt_len)
            blk = self.page_table[np.asarray(slot_ids)][:, t // self.block_size]
            off = np.broadcast_to(t % self.block_size, (n, prompt_len))
            blk = jnp.asarray(blk, jnp.int32)
            off = jnp.asarray(off, jnp.int32)
        else:
            blk = off = None

        def write(full, part, meta):
            kind, ax = meta
            if kind == "paged":
                return _write_paged(full, part, ax, blk, off)
            part = jax.lax.slice_in_dim(part, 0, n, axis=ax)
            return _write_rows(full, part, ax, ids)

        self.cache = jax.tree.map(write, self.cache, part_cache, self._meta)

    # -- memory accounting -------------------------------------------------

    def _paged_leaf_bytes_per_block(self) -> int:
        total = []

        def acc(c: cache_lib.CP):
            if "kv_blocks" in c.axes:
                per = np.dtype(c.dtype).itemsize
                for a, s in zip(c.axes, c.shape):
                    if a not in ("kv_blocks",):
                        per *= s
                total.append(per)
            return c
        jax.tree.map(acc, cache_lib.declare_paged_cache(
            self.cfg, self.capacity, self.num_blocks, self.block_size,
            self.dtype), is_leaf=lambda x: isinstance(x, cache_lib.CP))
        return int(sum(total))

    def memory_stats(self) -> dict:
        per_block = self._paged_leaf_bytes_per_block()
        per_token = per_block // self.block_size if self.block_size else 0
        return {
            "block_size": self.block_size,
            "num_blocks": self.num_blocks,
            "kv_bytes_per_block": per_block,
            "kv_arena_bytes": per_block * self.num_blocks,
            "kv_high_water_bytes": per_block * self.blocks.high_water,
            "kv_high_water_blocks": self.blocks.high_water,
            # sharded pools: per-data-shard peaks (BENCH json records the
            # shard balance the shard-aware allocator achieved)
            "data_shards": self.data_shards,
            "kv_high_water_blocks_by_shard":
                list(self.blocks.high_water_by_shard),
            # prefix cache: peak blocks mapped by >1 reference, live
            # index entries, LRU evictions, copy-on-write block copies
            "kv_shared_high_water_blocks": self.blocks.shared_high_water,
            "prefix_index_entries": self.prefix_index_entries(),
            "prefix_evictions": self.prefix_evictions,
            "prefix_cow_copies": self.prefix_cow_copies,
            # what the one-page-per-request arena (PR 1) would allocate
            "dense_equiv_bytes": per_token * self.capacity * self.max_seq,
        }


def _prompt_len(part_cache, meta_tree) -> Optional[int]:
    """Seq length of the packed prefill cache's first attention leaf."""
    leaves_p, _ = jax.tree.flatten(part_cache)
    leaves_m, _ = jax.tree.flatten(meta_tree,
                                   is_leaf=lambda x: isinstance(x, tuple))
    for part, (kind, ax) in zip(leaves_p, leaves_m):
        if kind == "paged":
            return part.shape[ax + 1]
    return None


class DenseTierSlotPool:
    """The PR 1 one-page-per-request arena (``[capacity, max_seq, ...]``
    rows): kept as the dense reference the paged pool is validated
    against (``CascadeEngine(use_paged_kv=False)``).  ``mesh`` shards the
    request rows over the data axes (no block accounting to shard)."""

    def __init__(self, cfg, capacity: int, max_seq: int, dtype=jnp.float32,
                 *, mesh=None):
        self.cfg = cfg
        self.capacity = capacity
        self.max_seq = max_seq
        self.dtype = dtype
        self.mesh = mesh
        self.data_shards = data_axis_size(mesh)
        if capacity % self.data_shards:
            raise ValueError(
                f"capacity {capacity} must divide into {self.data_shards} "
                "data shards")
        self.cache = cache_lib.init_cache(cfg, capacity, max_seq, dtype)
        decl = cache_lib.declare_cache(cfg, capacity, max_seq, dtype)
        if mesh is not None:
            shardings = jax.tree.map(
                lambda c: NamedSharding(
                    mesh, cache_lib.cache_spec_leaf(c, mesh, shard_seq=False)),
                decl, is_leaf=lambda x: isinstance(x, cache_lib.CP))
            self.cache = jax.device_put(self.cache, shardings)
        self._bax = jax.tree.map(
            lambda c: c.axes.index("batch"), decl,
            is_leaf=lambda x: isinstance(x, cache_lib.CP))

    def write_prefill(self, slot_ids: Sequence[int], part_cache) -> None:
        n = len(slot_ids)
        ids = jnp.asarray(slot_ids, jnp.int32)
        part = jax.tree.map(
            lambda a, bax: jax.lax.slice_in_dim(a, 0, n, axis=bax),
            part_cache, self._bax)
        self.cache = jax.tree.map(
            lambda full, p, bax: _write_rows(full, p, bax, ids),
            self.cache, part, self._bax)

    def memory_stats(self) -> dict:
        nbytes = []

        def acc(c):
            if "kv_seq" in getattr(c, "axes", ()):
                nbytes.append(int(np.prod(c.shape))
                              * np.dtype(c.dtype).itemsize)
            return c
        jax.tree.map(acc, cache_lib.declare_cache(
            self.cfg, self.capacity, self.max_seq, self.dtype),
            is_leaf=lambda x: isinstance(x, cache_lib.CP))
        total = int(sum(nbytes))
        return {
            "block_size": self.max_seq,
            "num_blocks": self.capacity,
            "kv_arena_bytes": total,
            "kv_high_water_bytes": total,
            "data_shards": self.data_shards,
            "dense_equiv_bytes": total,
        }
