"""Serving observability: request/tick tracing, streaming gate
calibration, and profiler hooks.

The paper's argument is that cascade quality is governed by the
*calibration* of the gate confidence — so the serving stack must treat
gate confidence as a first-class observable, not a scalar dumped at
exit.  Three instruments, all zero-cost when disabled:

* :class:`Tracer` — a structured host-side tracer.  The engine records
  per-request lifecycle spans (QUEUED → PREFILL → DECODE → ESCALATED →
  DONE, one async track per request id under its tier's process row)
  and per-tick phase events (admit, plan, launch, device_get, finish)
  into a bounded ring buffer, exported as Chrome trace-event JSON that
  loads directly in Perfetto (``serve_async --trace-out trace.json``).
  A stall, an escalation storm, or a host-sync bubble is then visible
  on a timeline instead of inferred from counters.  Events are built
  only from values the tick already fetched — tracing adds **no** host
  syncs (test-asserted traced-vs-untraced).
* :class:`GateCalibration` — streaming calibration telemetry: per-gate
  confidence histograms, reliability bins (binned confidence vs
  realized correctness), and streaming ECE — overall and per
  prompt-length bucket.  The online correctness proxy is the
  **escalation outcome**: when an escalated request finishes, the
  expensive tier's token stream either agrees with the cheap tier's
  (the gate escalated needlessly — the cheap answer was "correct") or
  disagrees (the escalation bought a different answer).  The proxy is
  only observed for *escalated* traffic (confidence ≤ δ), so the
  reliability diagram covers the low-confidence slice — see
  docs/serving.md for the selection-bias caveat.
* profiler hooks — :func:`annotation` / :func:`step_annotation` wrap
  ``jax.profiler.TraceAnnotation`` / ``StepTraceAnnotation`` so device
  traces (``serve_async --jax-profile DIR``) carry the same tick ids
  and launch names as the host tracer.

``length_bucket`` lives here (re-exported by ``serving/metrics.py``)
so both the metrics and the calibration telemetry bucket prompt
lengths identically without a circular import.
"""
from __future__ import annotations

import contextlib
import json
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np


def length_bucket(n: int) -> str:
    """Power-of-two prompt-length bucket label ("1", "2", "3-4", "5-8",
    "9-16", ...)."""
    hi = 1
    while hi < n:
        hi *= 2
    lo = hi // 2 + 1
    return str(hi) if lo >= hi else f"{lo}-{hi}"


# ---------------------------------------------------------------------------
# Structured tracer (Chrome trace-event / Perfetto export)
# ---------------------------------------------------------------------------

# track layout: pid 0 carries the engine's per-tick phase events (one
# tid per tier, plus one extra tid for the whole-tick span); pid
# REQUEST_PID_BASE + tier carries that tier's request lifecycle spans
# as async events keyed by request id.
ENGINE_PID = 0
REQUEST_PID_BASE = 1000


class Tracer:
    """Bounded ring buffer of Chrome trace events.

    All timestamps come from the tracer's own monotonic wall clock
    (``time.perf_counter_ns``-based microseconds), independent of the
    engine's — possibly virtual — clock, so host-time bubbles are real
    on the timeline even in deterministic runs.  The ring holds the
    most recent ``capacity`` events (``dropped`` counts evictions);
    export emits the surviving window plus track-naming metadata.
    """

    def __init__(self, capacity: int = 1 << 18):
        if capacity <= 0:
            raise ValueError("tracer capacity must be positive")
        self.capacity = capacity
        self._events: deque = deque(maxlen=capacity)
        self.dropped = 0
        self._open_req: Dict[int, dict] = {}     # rid -> open async span
        self._tracks: Dict[tuple, str] = {}      # (pid, tid) -> name
        self._pids: Dict[int, str] = {}
        self._t0 = time.perf_counter_ns()

    # -- clock --------------------------------------------------------------

    def now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    # -- low-level event append --------------------------------------------

    def _append(self, ev: dict) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    def name_process(self, pid: int, name: str) -> None:
        self._pids[pid] = name

    def name_track(self, pid: int, tid: int, name: str) -> None:
        self._tracks[(pid, tid)] = name

    # -- engine phase events (complete "X" events) --------------------------

    def phase(self, name: str, tid: int, t0_us: float,
              t1_us: Optional[float] = None, **args) -> None:
        """One completed engine phase on pid 0, track ``tid`` (tier
        index, or the extra whole-tick lane): an "X" event from
        ``t0_us`` to ``t1_us`` (default: now)."""
        t1 = self.now_us() if t1_us is None else t1_us
        self._append({"name": name, "ph": "X", "ts": t0_us,
                      "dur": max(t1 - t0_us, 0.0), "pid": ENGINE_PID,
                      "tid": tid, "args": args})

    @contextlib.contextmanager
    def span(self, name: str, tid: int, **args):
        """``with tracer.span("admit", tid=tier, tick=k): ...`` — times
        the body and appends the phase event."""
        t0 = self.now_us()
        try:
            yield
        finally:
            self.phase(name, tid, t0, **args)

    def instant(self, name: str, tid: int, **args) -> None:
        self._append({"name": name, "ph": "i", "ts": self.now_us(),
                      "pid": ENGINE_PID, "tid": tid, "s": "t",
                      "args": args})

    def prefix_cache_event(self, tier: int, rid: int, cached_tokens: int,
                           prompt_tokens: int, **args) -> None:
        """One prefix-cache lookup at admission, as an instant on the
        tier's engine lane: ``prefix_cache_hit`` when a cached prefix
        was mapped (args carry how many of the prompt's tokens it
        covers), ``prefix_cache_miss`` otherwise."""
        self.instant(
            "prefix_cache_hit" if cached_tokens else "prefix_cache_miss",
            tier, rid=rid, cached_tokens=int(cached_tokens),
            prompt_tokens=int(prompt_tokens), **args)

    def counter(self, name: str, value: float, tid: int = 0) -> None:
        """A counter track sample (queue depth, live rows, ...)."""
        self._append({"name": name, "ph": "C", "ts": self.now_us(),
                      "pid": ENGINE_PID, "tid": tid,
                      "args": {"value": float(value)}})

    # -- request lifecycle (async "b"/"e" spans keyed by rid) ---------------

    def request_transition(self, rid: int, state: str, tier: int,
                           shard: Optional[int] = None, **args) -> None:
        """Close the request's open lifecycle span (if any) and open a
        new one named ``state`` on the tier's request track.  Async
        events keyed by ``rid`` may overlap freely on one track —
        Perfetto renders each request id on its own sub-lane."""
        now = self.now_us()
        self._close_req(rid, now)
        pid = REQUEST_PID_BASE + tier
        ev = {"name": state, "ph": "b", "cat": "request", "id": rid,
              "ts": now, "pid": pid, "tid": int(shard or 0),
              "args": dict(args)}
        self._append(ev)
        self._open_req[rid] = ev

    def request_done(self, rid: int, tier: int,
                     shard: Optional[int] = None,
                     state: str = "DONE", **args) -> None:
        """Terminal transition: close the open span and mark the
        terminal `state` (DONE, or the overload terminals SHED/FAILED)
        as an instant on the tier's request track."""
        now = self.now_us()
        self._close_req(rid, now)
        self._append({"name": state, "ph": "i", "ts": now,
                      "pid": REQUEST_PID_BASE + tier,
                      "tid": int(shard or 0), "s": "t",
                      "args": dict(rid=rid, **args)})

    def _close_req(self, rid: int, now_us: float) -> None:
        open_ev = self._open_req.pop(rid, None)
        if open_ev is not None:
            self._append({"name": open_ev["name"], "ph": "e",
                          "cat": "request", "id": rid, "ts": now_us,
                          "pid": open_ev["pid"], "tid": open_ev["tid"],
                          "args": {}})

    # -- export -------------------------------------------------------------

    def events(self) -> List[dict]:
        return list(self._events)

    def trace_dict(self) -> dict:
        """The Chrome trace-event JSON object: track metadata + the ring's
        surviving events (a truncated ring may open with orphan "e"
        closes — Perfetto tolerates them; ``scripts/check_trace.py``
        knows the ring semantics)."""
        meta = []
        for pid, name in sorted(self._pids.items()):
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": name}})
            meta.append({"name": "process_sort_index", "ph": "M",
                         "pid": pid, "tid": 0, "args": {"sort_index": pid}})
        for (pid, tid), name in sorted(self._tracks.items()):
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tid, "args": {"name": name}})
        return {"traceEvents": meta + self.events(),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export(self, path: str) -> int:
        """Write Perfetto-loadable JSON; returns the event count."""
        trace = self.trace_dict()
        with open(path, "w") as f:
            json.dump(trace, f)
        return len(trace["traceEvents"])


# ---------------------------------------------------------------------------
# Streaming gate-calibration telemetry
# ---------------------------------------------------------------------------


class ReliabilityBins:
    """Streaming reliability diagram: fixed confidence bins accumulating
    (count, Σconf, Σcorrect) so ECE is computable at any point without
    storing samples.  Bin ``i`` covers ``[i/bins, (i+1)/bins)`` (the
    last bin closed at 1.0)."""

    def __init__(self, bins: int = 10):
        if bins <= 0:
            raise ValueError("need at least one bin")
        self.bins = bins
        self.count = np.zeros(bins, np.int64)
        self.conf_sum = np.zeros(bins, np.float64)
        self.correct_sum = np.zeros(bins, np.float64)

    def record(self, conf: float, correct: bool) -> None:
        i = min(int(conf * self.bins), self.bins - 1)
        i = max(i, 0)
        self.count[i] += 1
        self.conf_sum[i] += conf
        self.correct_sum[i] += 1.0 if correct else 0.0

    @property
    def total(self) -> int:
        return int(self.count.sum())

    def ece(self) -> float:
        """Expected Calibration Error over the streamed samples:
        Σ_b (n_b/N)·|conf̄_b − acc̄_b| (Guo et al. 2017).  NaN until a
        sample arrives."""
        n = self.total
        if n == 0:
            return float("nan")
        mask = self.count > 0
        avg_conf = self.conf_sum[mask] / self.count[mask]
        avg_acc = self.correct_sum[mask] / self.count[mask]
        w = self.count[mask] / n
        return float(np.sum(w * np.abs(avg_conf - avg_acc)))

    def diagram(self) -> List[dict]:
        """Per-bin reliability rows (lo, hi, n, mean conf, realized
        accuracy) — empty bins keep n=0 with NaN means."""
        out = []
        for i in range(self.bins):
            n = int(self.count[i])
            out.append({
                "lo": i / self.bins,
                "hi": (i + 1) / self.bins,
                "n": n,
                "conf": self.conf_sum[i] / n if n else float("nan"),
                "acc": self.correct_sum[i] / n if n else float("nan"),
            })
        return out


class GateCalibration:
    """Per-gate streaming calibration state.

    Two streams feed it:

    * every gate decision (``record_gate``) — confidence histogram over
      all gated traffic, plus the escalate/keep split per bin;
    * every **escalation outcome** (``record_outcome``) — when an
      escalated request completes, agreement between the cheap and
      expensive tiers' token streams is the online correctness proxy
      feeding the reliability bins (overall and per prompt-length
      bucket);
    * every **verify outcome** (``record_verify_outcome``) — under
      speculative cascade decoding, each drafted token the expensive
      tier scored is a per-token agreement sample at the draft tier's
      gate.  Unlike escalation outcomes this stream is *ground truth*
      with no selection bias: the verifier scores every draft position
      regardless of the gate's decision, so its reliability bins cover
      the full confidence range, not just the escalated tail.
    """

    def __init__(self, n_gates: int, bins: int = 10):
        self.n_gates = n_gates
        self.bins = bins
        self.conf_hist = [np.zeros(bins, np.int64) for _ in range(n_gates)]
        self.esc_hist = [np.zeros(bins, np.int64) for _ in range(n_gates)]
        self.reliability = [ReliabilityBins(bins) for _ in range(n_gates)]
        self.reliability_by_bucket: List[Dict[str, ReliabilityBins]] = [
            {} for _ in range(n_gates)]
        self.outcomes = [0] * n_gates
        self.agreements = [0] * n_gates
        self.verify_outcomes = [0] * n_gates
        self.verify_accepts = [0] * n_gates

    def record_gate(self, gate: int, conf: float, escalated: bool) -> None:
        i = min(max(int(conf * self.bins), 0), self.bins - 1)
        self.conf_hist[gate][i] += 1
        if escalated:
            self.esc_hist[gate][i] += 1

    def record_outcome(self, gate: int, conf: float, agree: bool,
                       prompt_len: Optional[int] = None) -> None:
        self.outcomes[gate] += 1
        if agree:
            self.agreements[gate] += 1
        self.reliability[gate].record(conf, agree)
        if prompt_len is not None:
            bucket = length_bucket(prompt_len)
            by = self.reliability_by_bucket[gate]
            if bucket not in by:
                by[bucket] = ReliabilityBins(self.bins)
            by[bucket].record(conf, agree)

    def record_verify_outcome(self, gate: int, conf: float,
                              accepted: bool) -> None:
        """One speculative verify decision at `gate`: the draft tier
        emitted a token with confidence `conf` and the verify tier's
        argmax `accepted` (or rejected) it.  Streams into the same
        reliability bins escalation outcomes feed — per-token rather
        than per-sequence, and bias-free (every draft is scored)."""
        self.verify_outcomes[gate] += 1
        if accepted:
            self.verify_accepts[gate] += 1
        self.reliability[gate].record(conf, accepted)

    # -- readouts -----------------------------------------------------------

    def verify_accept_rate(self, gate: int) -> float:
        n = self.verify_outcomes[gate]
        return self.verify_accepts[gate] / n if n else float("nan")

    def ece(self, gate: int) -> float:
        return self.reliability[gate].ece()

    def agreement_rate(self, gate: int) -> float:
        n = self.outcomes[gate]
        return self.agreements[gate] / n if n else float("nan")

    def summary(self) -> List[dict]:
        """Per-gate calibration block for ``ServingMetrics.summary()``
        and the BENCH json (plain lists: JSON-serializable)."""
        out = []
        for g in range(self.n_gates):
            by_bucket = {
                b: {"ece": r.ece(), "n": r.total}
                for b, r in sorted(
                    self.reliability_by_bucket[g].items(),
                    key=lambda kv: int(kv[0].split("-")[0]))}
            out.append({
                "gate": g,
                "seen": int(self.conf_hist[g].sum()),
                "conf_hist": self.conf_hist[g].tolist(),
                "esc_hist": self.esc_hist[g].tolist(),
                "bin_edges": [i / self.bins for i in range(self.bins + 1)],
                "outcomes": self.outcomes[g],
                "agreement_rate": self.agreement_rate(g),
                "verify_outcomes": self.verify_outcomes[g],
                "verify_accept_rate": self.verify_accept_rate(g),
                "ece": self.ece(g),
                "reliability": self.reliability[g].diagram(),
                "ece_by_prompt_bucket": by_bucket,
            })
        return out


# ---------------------------------------------------------------------------
# jax profiler hooks
# ---------------------------------------------------------------------------

NULL_CONTEXT = contextlib.nullcontext()


def annotation(name: str, enabled: bool = True):
    """A named ``jax.profiler.TraceAnnotation`` scope (a no-op context
    when ``enabled`` is False or the profiler is unavailable).  Wraps
    the engine's launches so device traces show ``run_mixed/<tier>``
    etc. alongside XLA's own annotations."""
    if not enabled:
        return NULL_CONTEXT
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except (ImportError, AttributeError):            # pragma: no cover
        return NULL_CONTEXT


def step_annotation(tick: int, enabled: bool = True):
    """``jax.profiler.StepTraceAnnotation`` for one engine tick: device
    trace viewers group work by ``step_num``, which the engine sets to
    its tick id — the join key between a device trace and the host
    tracer's phase events."""
    if not enabled:
        return NULL_CONTEXT
    try:
        import jax.profiler
        return jax.profiler.StepTraceAnnotation("tick", step_num=tick)
    except (ImportError, AttributeError):            # pragma: no cover
        return NULL_CONTEXT


@contextlib.contextmanager
def profile_window(out_dir: Optional[str]):
    """An opt-in ``jax.profiler`` trace window (``serve_async
    --jax-profile DIR``): starts a device+host trace into ``out_dir``
    for the duration of the body.  None: no-op."""
    if not out_dir:
        yield
        return
    import jax.profiler
    jax.profiler.start_trace(out_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
