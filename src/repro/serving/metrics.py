"""Serving metrics: latency percentiles, throughput, utilization, and the
paper's Eq 7 cost accounting — unified with
:class:`repro.core.server.ServerStats` so offline (`CascadeServer`) and
online (`CascadeEngine`) runs report through the same structures.

Cost convention (matches ``CascadeServer.summary`` and Eq 7)::

    cost/request  = Σ_m (N_m / N) · cost_m      N_m = requests reaching m
    always-exp    = Σ_m cost_m                  (escalate everything)
    always-fast   = cost_0
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.server import GateStats, ServerStats
# canonical definition lives in observability (shared with the
# calibration telemetry); re-exported here for its historical home
from repro.serving.observability import GateCalibration, length_bucket  # noqa: F401
from repro.serving.request import Request


def percentile(values: Sequence[float], q: float) -> float:
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values, np.float64), q))


@dataclass
class TierCost:
    name: str
    flops_per_request: float


class ServingMetrics:
    """Aggregates per-request records + per-step occupancy counters."""

    def __init__(self, tiers: Sequence[TierCost],
                 slots_per_tier: Sequence[int]):
        self.tiers = list(tiers)
        self.slots_per_tier = list(slots_per_tier)
        n_gates = len(tiers) - 1
        self.stats = ServerStats(gates=[GateStats() for _ in range(n_gates)])
        self.latencies: List[float] = []
        self.ttfts: List[float] = []
        self.ttft_by_bucket: Dict[str, List[float]] = {}
        self.prompt_lens: List[int] = []
        self.tier_requests = [0] * len(tiers)   # N_m: requests reaching m
        self.busy_slot_steps = [0] * len(tiers)
        # padding tax: live prompt tokens actually belonging to requests
        # vs tokens the fixed-shape prefill batches processed (chunked
        # prefill keeps the ratio near 1; pad-to-max burns the difference)
        self.prefill_live_tokens = 0
        self.prefill_processed_tokens = 0
        # step-batch padding tax, per tier: live tokens each launch
        # really computed vs token slots its fixed-shape program
        # processed (ragged flat layout: bucket padding only; padded
        # mixed program: capacity * width; split: both launches).  The
        # wasted-slot ratio in summary() is 1 - live/processed; the
        # per-tick series feeds the bench sweep's per-point ratio.
        self.step_live_tokens = [0] * len(tiers)
        self.step_processed_tokens = [0] * len(tiers)
        # launch efficiency: compiled-program dispatches and blocking
        # device->host fetches, per tier (the unified token-batch path's
        # win: one launch + one device_get per active tier per tick; the
        # split path pays two launches on mixed prefill+decode ticks)
        self.launches_by_tier = [0] * len(tiers)
        self.host_syncs_by_tier = [0] * len(tiers)
        # streaming gate-calibration telemetry: per-gate confidence
        # histograms + reliability bins fed by escalation outcomes
        # (scheduler records decisions, engine records outcomes)
        self.calibration = GateCalibration(n_gates)
        # overload-and-failure accounting: submissions (conservation
        # denominator), deadline-shed and retry-failed requests per tier
        # they were queued for / running on, preemptions with the tokens
        # they discarded (prefilled prompt + generated tokens, all
        # recomputed at replay), and transient launch-attempt retries
        self.submitted = 0
        self.shed_by_tier = [0] * len(tiers)
        self.failed_by_tier = [0] * len(tiers)
        self.preemptions_by_tier = [0] * len(tiers)
        self.replayed_tokens_by_tier = [0] * len(tiers)
        self.retries_by_tier = [0] * len(tiers)
        # speculative cascade decoding, indexed by the *verify* tier:
        # drafted counts verified draft positions, accepted those the
        # scoring model's argmax confirmed (rolled_back = the rest, whose
        # provisional KV writes were discarded)
        self.spec_drafted_by_tier = [0] * len(tiers)
        self.spec_accepted_by_tier = [0] * len(tiers)
        self.spec_rolled_back_by_tier = [0] * len(tiers)
        # prefix-cache telemetry (engine records one lookup per chunked
        # admission when the cache is enabled): hits are admissions that
        # mapped a cached prefix; cached_prefix_tokens are prompt tokens
        # served from shared KV blocks — prefill work (and admission
        # budget) the cascade never paid
        self.prefix_lookups_by_tier = [0] * len(tiers)
        self.prefix_hits_by_tier = [0] * len(tiers)
        self.prefix_cached_tokens_by_tier = [0] * len(tiers)
        self.prefix_prompt_tokens_by_tier = [0] * len(tiers)
        # per-tick wall-time intervals (the engine passes each tick's
        # clock reading to record_step; consecutive deltas feed the
        # tick-duration histogram in summary())
        self.tick_durations: List[float] = []
        self._last_step_time: Optional[float] = None
        self.steps = 0
        # throughput window: first arrival -> last completion (makespan),
        # not first->last engine step (zero for single-step runs)
        self.first_arrival: Optional[float] = None
        self.last_finish: Optional[float] = None

    # -- recording ---------------------------------------------------------

    def record_admission(self, tier: int, n: int = 1) -> None:
        self.tier_requests[tier] += n
        self.stats.cost += self.tiers[tier].flops_per_request * n
        if tier == 0:
            self.stats.requests += n

    def record_submitted(self, n: int = 1) -> None:
        """A request entered the system (the conservation denominator:
        at drain, submitted == completed + shed + failed)."""
        self.submitted += n

    def record_shed(self, tier: int, n: int = 1) -> None:
        """`n` queued requests rejected by the load-shedding pass."""
        self.shed_by_tier[tier] += n

    def record_failed(self, tier: int, n: int = 1) -> None:
        """`n` live requests sacrificed to exhausted launch retries."""
        self.failed_by_tier[tier] += n

    def record_preemption(self, tier: int, replayed_tokens: int) -> None:
        """One row evicted by the preemption policy; `replayed_tokens`
        counts the discarded work (prefilled prompt tokens + generated
        tokens) the replay will recompute."""
        self.preemptions_by_tier[tier] += 1
        self.replayed_tokens_by_tier[tier] += int(replayed_tokens)

    def record_retry(self, tier: int, n: int = 1) -> None:
        """`n` transient launch-attempt failures absorbed by the
        engine's bounded retry-with-backoff path."""
        self.retries_by_tier[tier] += n

    def record_step(self, active_per_tier: Sequence[int], now: float) -> None:
        self.steps += 1
        for t, n in enumerate(active_per_tier):
            self.busy_slot_steps[t] += n
        # per-tick wall-time interval (clock domain: seconds, or ticks
        # under a VirtualClock) — the engine-health histogram a latency
        # percentile can't show (one slow tick hides inside p95)
        if self._last_step_time is not None and now >= self._last_step_time:
            self.tick_durations.append(now - self._last_step_time)
        self._last_step_time = now

    def record_gate_outcomes(self, req: Request) -> None:
        """Stream a completed *escalated* request's outcomes into the
        calibration telemetry: for each gate it crossed, did the next
        tier's token stream agree with the one the gate rejected?
        Agreement is the online correctness proxy — observable only for
        escalated traffic (see docs/serving.md for the caveat)."""
        for g in range(req.tier):
            agree = req.tokens_by_tier[g] == req.tokens_by_tier[g + 1]
            self.calibration.record_outcome(
                g, req.seq_conf_by_tier[g], agree, req.prompt_tokens)

    def record_speculation(self, tier: int, drafted: int,
                           accepted: int) -> None:
        """One verify window resolved on `tier`: `drafted` draft
        positions scored, `accepted` confirmed (the rest rolled back)."""
        self.spec_drafted_by_tier[tier] += int(drafted)
        self.spec_accepted_by_tier[tier] += int(accepted)
        self.spec_rolled_back_by_tier[tier] += int(drafted - accepted)

    def record_prefix_lookup(self, tier: int, cached_tokens: int,
                             prompt_tokens: int) -> None:
        """One prefix-cache lookup at admission: `cached_tokens` of the
        request's `prompt_tokens` were served from shared KV blocks
        (0 on a miss)."""
        self.prefix_lookups_by_tier[tier] += 1
        if cached_tokens:
            self.prefix_hits_by_tier[tier] += 1
            self.prefix_cached_tokens_by_tier[tier] += int(cached_tokens)
        self.prefix_prompt_tokens_by_tier[tier] += int(prompt_tokens)

    def record_prefill_tokens(self, live: int, processed: int) -> None:
        """One prefill execution: `live` real prompt tokens inside a
        fixed-shape batch of `processed` token slots."""
        self.prefill_live_tokens += int(live)
        self.prefill_processed_tokens += int(processed)

    def record_step_tokens(self, tier: int, live: int,
                           processed: int) -> None:
        """One token-batch launch of `tier`: `live` real tokens inside a
        compiled program that processed `processed` token slots."""
        self.step_live_tokens[tier] += int(live)
        self.step_processed_tokens[tier] += int(processed)

    def record_launches(self, tier: int, n: int = 1) -> None:
        """`n` compiled-program dispatches (prefill/chunk/decode/mixed
        launches) for `tier` this tick."""
        self.launches_by_tier[tier] += n

    def record_host_sync(self, tier: int, n: int = 1) -> None:
        """One blocking ``device_get`` paid by `tier`."""
        self.host_syncs_by_tier[tier] += n

    def record_completion(self, req: Request) -> None:
        self.latencies.append(req.latency)
        self.prompt_lens.append(req.prompt_tokens)
        if req.ttft is not None:
            self.ttfts.append(req.ttft)
            self.ttft_by_bucket.setdefault(
                length_bucket(req.prompt_tokens), []).append(req.ttft)
        if self.first_arrival is None \
                or req.arrival_time < self.first_arrival:
            self.first_arrival = req.arrival_time
        if self.last_finish is None or req.finish_time > self.last_finish:
            self.last_finish = req.finish_time

    def sync_gate_stats(self, gate_stats: Sequence[GateStats]) -> None:
        """Mirror the scheduler's gate counters into ServerStats."""
        for mine, theirs in zip(self.stats.gates, gate_stats):
            mine.seen = theirs.seen
            mine.escalated = theirs.escalated

    # -- summary -----------------------------------------------------------

    def conservation(self) -> dict:
        """Request conservation: every submitted request must end DONE,
        SHED, or FAILED (``in_flight`` is the residue — nonzero only
        mid-run; at drain ``ok`` must hold)."""
        done = len(self.latencies)
        shed = sum(self.shed_by_tier)
        failed = sum(self.failed_by_tier)
        in_flight = self.submitted - done - shed - failed
        return {"submitted": self.submitted, "completed": done,
                "shed": shed, "failed": failed, "in_flight": in_flight,
                "ok": in_flight == 0}

    @property
    def elapsed(self) -> float:
        """First arrival -> last completion (makespan)."""
        if self.first_arrival is None or self.last_finish is None:
            return 0.0
        return self.last_finish - self.first_arrival

    def tick_duration_hist(self) -> Dict[str, int]:
        """Decade histogram of per-tick wall intervals ("1e-3" counts
        ticks with 1ms <= dt < 10ms): coarse, but a bimodal tick time —
        the stall / recompile / host-sync-bubble signature — shows up
        as two occupied decades no percentile reveals."""
        hist: Dict[str, int] = {}
        for d in self.tick_durations:
            key = "0" if d <= 0 else f"1e{int(np.floor(np.log10(d)))}"
            hist[key] = hist.get(key, 0) + 1
        return dict(sorted(hist.items(),
                           key=lambda kv: float(kv[0])))

    def snapshot(self, now: float) -> dict:
        """A cheap point-in-time readout for the periodic
        ``--metrics-interval`` line: progress, escalation, and the
        streaming calibration state (per-gate ECE + agreement)."""
        return {
            "t": now,
            "requests": self.stats.requests,
            "completed": len(self.latencies),
            "steps": self.steps,
            "escalation_rates": [g.escalation_rate
                                 for g in self.stats.gates],
            "gate_ece": [self.calibration.ece(g)
                         for g in range(self.calibration.n_gates)],
            "gate_agreement": [self.calibration.agreement_rate(g)
                               for g in range(self.calibration.n_gates)],
            "gate_outcomes": list(self.calibration.outcomes),
            "tick_duration_p50": percentile(self.tick_durations, 50),
            "shed": sum(self.shed_by_tier),
            "preemptions": sum(self.preemptions_by_tier),
            "failed": sum(self.failed_by_tier),
        }

    def summary(self) -> dict:
        n = max(self.stats.requests, 1)
        elapsed = self.elapsed
        flops_cascade = self.stats.cost / n          # Eq 7 realized
        flops_always_exp = sum(t.flops_per_request for t in self.tiers)
        util = [self.busy_slot_steps[t] / max(self.steps * c, 1)
                for t, c in enumerate(self.slots_per_tier)]
        return {
            "requests": self.stats.requests,
            "completed": len(self.latencies),
            "steps": self.steps,
            "elapsed": elapsed,
            "throughput": (len(self.latencies) / elapsed
                           if elapsed > 0 else float("nan")),
            "latency_p50": percentile(self.latencies, 50),
            "latency_p95": percentile(self.latencies, 95),
            "ttft_p50": percentile(self.ttfts, 50),
            "ttft_p95": percentile(self.ttfts, 95),
            "ttft_p50_by_prompt_bucket": {
                b: percentile(v, 50)
                for b, v in sorted(
                    self.ttft_by_bucket.items(),
                    key=lambda kv: int(kv[0].split("-")[0]))},
            "prompt_len_mean": (float(np.mean(self.prompt_lens))
                                if self.prompt_lens else float("nan")),
            "prompt_len_max": (max(self.prompt_lens)
                               if self.prompt_lens else 0),
            "prefill_live_tokens": self.prefill_live_tokens,
            "prefill_processed_tokens": self.prefill_processed_tokens,
            "prefill_live_token_ratio": (
                self.prefill_live_tokens / self.prefill_processed_tokens
                if self.prefill_processed_tokens else float("nan")),
            "step_live_tokens": sum(self.step_live_tokens),
            "step_processed_tokens": sum(self.step_processed_tokens),
            "step_live_tokens_by_tier": list(self.step_live_tokens),
            "step_processed_tokens_by_tier":
                list(self.step_processed_tokens),
            # the padding tax of the token-batch executors: fraction of
            # processed token slots that held no live token (the ragged
            # flat layout's whole point is driving this toward 0)
            "wasted_slot_ratio": (
                1.0 - sum(self.step_live_tokens)
                / sum(self.step_processed_tokens)
                if sum(self.step_processed_tokens) else float("nan")),
            "wasted_slot_ratio_by_tier": [
                1.0 - l / p if p else float("nan")
                for l, p in zip(self.step_live_tokens,
                                self.step_processed_tokens)],
            "launches": list(self.launches_by_tier),
            "launches_per_tick": [
                n / self.steps if self.steps else float("nan")
                for n in self.launches_by_tier],
            "host_syncs": list(self.host_syncs_by_tier),
            "host_syncs_per_tick": [
                n / self.steps if self.steps else float("nan")
                for n in self.host_syncs_by_tier],
            "tick_duration_p50": percentile(self.tick_durations, 50),
            "tick_duration_p95": percentile(self.tick_durations, 95),
            "tick_duration_max": (max(self.tick_durations)
                                  if self.tick_durations else float("nan")),
            "tick_duration_hist": self.tick_duration_hist(),
            "tier_names": [t.name for t in self.tiers],
            "tier_requests": list(self.tier_requests),
            "tier_utilization": util,
            # overload-and-failure surface: shed rate is over submissions
            # (a request shed before admission never counts as a request)
            "submitted": self.submitted,
            "shed": sum(self.shed_by_tier),
            "shed_by_tier": list(self.shed_by_tier),
            "shed_rate": (sum(self.shed_by_tier) / self.submitted
                          if self.submitted else 0.0),
            "failed": sum(self.failed_by_tier),
            "failed_by_tier": list(self.failed_by_tier),
            "preemptions": sum(self.preemptions_by_tier),
            "preemptions_by_tier": list(self.preemptions_by_tier),
            "replayed_tokens": sum(self.replayed_tokens_by_tier),
            "replayed_tokens_by_tier": list(self.replayed_tokens_by_tier),
            "launch_retries": sum(self.retries_by_tier),
            "launch_retries_by_tier": list(self.retries_by_tier),
            # prefix cache: hit rate over lookups, tokens served from
            # shared blocks (the prefill work saved), and the fraction
            # of all admitted prompt tokens the cache absorbed
            "prefix_cache": {
                "lookups": sum(self.prefix_lookups_by_tier),
                "hits": sum(self.prefix_hits_by_tier),
                "hit_rate": (sum(self.prefix_hits_by_tier)
                             / sum(self.prefix_lookups_by_tier)
                             if sum(self.prefix_lookups_by_tier)
                             else float("nan")),
                "cached_tokens": sum(self.prefix_cached_tokens_by_tier),
                "cached_token_frac": (
                    sum(self.prefix_cached_tokens_by_tier)
                    / sum(self.prefix_prompt_tokens_by_tier)
                    if sum(self.prefix_prompt_tokens_by_tier)
                    else float("nan")),
                "hits_by_tier": list(self.prefix_hits_by_tier),
                "cached_tokens_by_tier":
                    list(self.prefix_cached_tokens_by_tier),
            },
            # speculative cascade decoding: accept rate over verified
            # drafts (the ROADMAP success metric's denominator) and the
            # raw draft/accept/rollback token counters per verify tier
            "speculation": {
                "drafted": sum(self.spec_drafted_by_tier),
                "accepted": sum(self.spec_accepted_by_tier),
                "rolled_back": sum(self.spec_rolled_back_by_tier),
                "accept_rate": (sum(self.spec_accepted_by_tier)
                                / sum(self.spec_drafted_by_tier)
                                if sum(self.spec_drafted_by_tier)
                                else float("nan")),
                "drafted_by_tier": list(self.spec_drafted_by_tier),
                "accepted_by_tier": list(self.spec_accepted_by_tier),
                "rolled_back_by_tier":
                    list(self.spec_rolled_back_by_tier),
            },
            "conservation": self.conservation(),
            "escalation_rates": [g.escalation_rate
                                 for g in self.stats.gates],
            # streaming gate calibration: per-gate confidence histogram,
            # reliability diagram + ECE from escalation outcomes
            # (overall and per prompt-length bucket)
            "gate_calibration": self.calibration.summary(),
            "flops_per_request_cascade": flops_cascade,
            "flops_per_request_always_fast":
                self.tiers[0].flops_per_request,
            "flops_per_request_always_expensive": flops_always_exp,
        }
