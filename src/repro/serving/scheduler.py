"""Continuous-batching scheduler with confidence-gated escalation queues.

One arrival queue feeds tier 0; each gate m owns an escalation queue
feeding tier m+1.  Every engine step the scheduler admits waiting requests
into free decode slots (continuous batching: admission happens mid-decode,
never waiting for the batch to drain), packing escalated requests densely
— the invariant is that after admission a tier never holds a free slot
while its queue has an admissible request.

δ per gate is either fixed, or derived online from an escalation *budget*
(:func:`repro.core.server.delta_for_escalation_rate` over a sliding window
of observed confidences — the deployment knob ported from
:class:`repro.core.server.CascadeServer`).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional, Sequence, Tuple

from repro.core.server import GateStats, delta_for_escalation_rate
from repro.serving.request import Request
from repro.serving.slots import SlotAllocator


@dataclass
class GateSpec:
    """Gate configuration: fixed δ or an escalation budget.

    Exactly one of ``delta`` / ``budget`` should be set.  In budget mode δ
    is the ``budget``-quantile of the last ``window`` observed sequence
    confidences; until ``min_calibration`` confidences are seen the
    initial ``delta_init`` is used.
    """
    delta: Optional[float] = None
    budget: Optional[float] = None
    window: int = 512
    min_calibration: int = 4
    delta_init: float = 0.5

    def __post_init__(self):
        if (self.delta is None) == (self.budget is None):
            raise ValueError("set exactly one of delta / budget")


class CascadeScheduler:
    """Queues + slot accounting for an M-tier cascade."""

    def __init__(self, slots_per_tier: Sequence[int],
                 gates: Sequence[GateSpec],
                 shards_per_tier: Optional[Sequence[int]] = None,
                 calibration=None):
        num_tiers = len(slots_per_tier)
        if len(gates) != num_tiers - 1:
            raise ValueError("one gate per non-final tier")
        self.num_tiers = num_tiers
        # sharded serving: a tier on a mesh with D data shards partitions
        # its rows into D contiguous ranges; admission targets one shard
        shards = ([1] * num_tiers if shards_per_tier is None
                  else [int(s) for s in shards_per_tier])
        if len(shards) != num_tiers:
            raise ValueError("one shard count per tier")
        self.allocators = [SlotAllocator(c, d)
                           for c, d in zip(slots_per_tier, shards)]
        self.gates = list(gates)
        self.gate_stats = [GateStats() for _ in gates]
        # streaming calibration telemetry sink (observability.
        # GateCalibration, usually ServingMetrics.calibration): every
        # gate decision streams (confidence, escalated) into it; the
        # engine streams escalation *outcomes* separately.  None: off.
        self.calibration = calibration
        self._conf_windows: List[Deque[float]] = [
            deque(maxlen=g.window) for g in gates]
        # queue[0] = arrivals; queue[m>0] = escalations from gate m-1
        self.queues: List[Deque[Request]] = [deque()
                                             for _ in range(num_tiers)]
        # exact admission-token accounting: tokens admit() charged
        # against its budget windows, per tier (the one-currency ledger:
        # under unified execution each admission bills its first chunk,
        # so this is the admitted prefill work in budget currency)
        self.admitted_tokens = [0] * num_tiers

    # -- submission --------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queues[0].append(req)

    def push_escalated(self, req: Request) -> None:
        self.queues[req.tier + 1].append(req)

    def requeue(self, req: Request, tier: int) -> None:
        """Put a preempted request back at the *head* of `tier`'s queue:
        it was already admitted once, so it outranks later arrivals for
        re-admission (starvation guard for the replay path)."""
        self.queues[tier].appendleft(req)

    # -- load shedding -------------------------------------------------------

    def shed(self, tier: int, now: float,
             floor: Optional[Callable[[Request], float]] = None,
             ) -> List[Request]:
        """Remove and return queued requests of `tier` that are past —
        or provably unable to meet — their deadline.  A request sheds
        when ``max(now, arrival) + floor(request) > deadline``:
        ``floor`` is a lower bound on its remaining service time
        (0 when not provided, so only already-expired deadlines shed).
        Deadline-less requests never shed.  The caller owns the state
        transition (``Request.shed``) and metrics/tracing."""
        q = self.queues[tier]
        if not q:
            return []
        shed: List[Request] = []
        kept: List[Request] = []
        for req in q:
            if req.deadline is not None and \
                    max(now, req.arrival_time) + \
                    (floor(req) if floor is not None else 0.0) \
                    > req.deadline:
                shed.append(req)
            else:
                kept.append(req)
        if shed:
            q.clear()
            q.extend(kept)
        return shed

    # -- admission (continuous batching) -----------------------------------

    def admissible(self, tier: int, now: float) -> bool:
        q = self.queues[tier]
        return bool(q) and (tier > 0 or q[0].arrival_time <= now)

    def peek(self, tier: int, now: float) -> Optional[Request]:
        """The queue head that :meth:`admit` would pop next (None if the
        queue is empty, not yet arrived, or the tier has no free slot).
        Lets the engine inspect prompt length / block demand before
        committing to the admission."""
        if not self.admissible(tier, now) \
                or self.allocators[tier].num_free == 0:
            return None
        return self.queues[tier][0]

    def admit(self, tier: int, now: float, limit: Optional[int] = None,
              token_budget: Optional[int] = None, budget_used: int = 0,
              shard: Optional[int] = None,
              token_cost=None, admitted_before: Optional[int] = None,
              ) -> Tuple[List[Request], List[int]]:
        """Pop requests into free slots of `tier` until either runs out.
        Returns the packed (requests, slot_ids) admitted this step.
        ``limit`` caps the number admitted (the engine's block-paged KV
        arena may run out of blocks before the tier runs out of rows).
        ``token_budget`` caps the total *tokens* admitted in one budget
        window — the admission knob: a tier should not accept more work
        per tick than its token batch can absorb.  ``budget_used``
        carries tokens already charged against the current window (the
        engine admits one request per call while binding KV blocks in
        between, with a per-tick window; under unified token-batch
        execution it also pre-charges the tick's carried compute load:
        one token per decoding row plus each mid-prefill row's next
        chunk — prefill chunks and decode tokens are one currency).
        ``token_cost`` maps a request to its budget charge — default its
        full prompt length (the legacy currency); the unified engine
        charges only the first chunk, since later chunks bill later
        ticks' windows, and with the prefix cache on both engine paths
        subtract the matched cached prefix first (tokens served from
        shared KV blocks are never prefilled, so they cost 0 admission
        budget).  The window's first *admitted request* is always
        admitted even when over budget (a prompt longer than the whole
        budget must not starve): with ``admitted_before`` (requests
        already admitted in this window) the guard keys on admissions,
        so a nonzero carried load cannot starve the head; without it the
        legacy ``budget_used == 0`` rule applies.  ``shard`` pins the
        admission to one data shard's row range (sharded serving: the
        engine picks the shard whose KV block pool can hold the
        request); None lets the allocator balance shards."""
        reqs: List[Request] = []
        slots: List[int] = []
        used = budget_used
        alloc = self.allocators[tier]
        while self.admissible(tier, now) and alloc.free_in(shard) > 0 \
                and (limit is None or len(reqs) < limit):
            head = self.queues[tier][0]
            need = (head.prompt_tokens if token_cost is None
                    else token_cost(head))
            first = (used == 0 if admitted_before is None
                     else admitted_before + len(reqs) == 0)
            if token_budget is not None and not first \
                    and used + need > token_budget:
                break
            slot = alloc.alloc(shard)
            req = self.queues[tier].popleft()
            req.admit(tier, slot, now)
            reqs.append(req)
            slots.append(slot)
            used += need
        self.admitted_tokens[tier] += used - budget_used
        return reqs, slots

    def release(self, tier: int, slot: int) -> None:
        self.allocators[tier].free(slot)

    # -- gating ------------------------------------------------------------

    def delta(self, gate: int) -> float:
        g = self.gates[gate]
        if g.delta is not None:
            return g.delta
        win = self._conf_windows[gate]
        if len(win) < g.min_calibration:
            return g.delta_init
        return delta_for_escalation_rate(list(win), g.budget)

    def gate_decision(self, gate: int, seq_conf: float,
                      force: Optional[bool] = None) -> bool:
        """Record `seq_conf` at `gate`; True -> escalate to tier gate+1.
        ``force`` overrides the threshold comparison (fault injection:
        escalation storms simulate a miscalibrated gate) — the forced
        decision still streams into the stats, confidence window, and
        calibration telemetry, exactly as a genuine one would."""
        delta = self.delta(gate)
        self._conf_windows[gate].append(seq_conf)
        st = self.gate_stats[gate]
        st.seen += 1
        escalate = seq_conf <= delta if force is None else bool(force)
        if escalate:
            st.escalated += 1
        if self.calibration is not None:
            self.calibration.record_gate(gate, seq_conf, escalate)
        return escalate

    # -- introspection -----------------------------------------------------

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.queues)

    def check_invariant(self, now: float) -> None:
        """Continuous-batching invariant: no tier has both a free slot and
        an admissible queued request (call after admission).  Holds for
        unbounded admission; a token-budget-limited tier may legitimately
        leave admissible requests queued past the budget (and a
        block-limited one past free KV blocks), so this is a test helper
        for fully-provisioned, budget-unconstrained runs."""
        for t in range(self.num_tiers):
            if self.allocators[t].num_free > 0 and self.admissible(t, now):
                raise AssertionError(
                    f"tier {t}: free slots with non-empty queue")
