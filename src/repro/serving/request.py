"""Request lifecycle for the async cascade runtime.

A request moves through::

    QUEUED -> PREFILL -> DECODE -> GATED -+-> DONE
       ^                                  |
       '---------- ESCALATED <------------'   (conf <= δ, next tier)

Escalated requests re-enter QUEUED-like waiting in the next tier's
escalation queue and are re-prefilled there (the expensive member decodes
from scratch, as in the paper's cascade — its quality, not the fast
model's draft, is what the gate bought).

Overload and failure add three more states (see docs/serving.md
"Overload and failure semantics"):

  * ``PREEMPTED`` — a live row evicted by the engine's preemption policy
    when the KV block pool runs dry.  The tier's partial work is
    discarded and the request re-queues at the head of its tier's queue;
    re-admission replays prefill (and, deterministically, the same
    decode) from scratch through the idempotent chunk machinery, so the
    replayed token stream is bit-identical to an uninterrupted run.
  * ``SHED`` (terminal) — a *queued* request rejected by the load-shedding
    pass because its deadline has passed or provably cannot be met.
  * ``FAILED`` (terminal) — a live request sacrificed when a launch's
    bounded retry budget exhausts on persistent transient errors (the
    engine fails one request, never the whole run).

Timestamps are recorded in the engine's clock domain (wall seconds or
virtual ticks): arrival, admission per tier, first token, finish.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    GATED = "gated"
    ESCALATED = "escalated"
    PREEMPTED = "preempted"   # evicted from a row; re-queued for replay
    SHED = "shed"             # terminal: deadline-rejected while queued
    FAILED = "failed"         # terminal: launch retries exhausted
    DONE = "done"


_ALLOWED = {
    RequestState.QUEUED: {RequestState.PREFILL, RequestState.SHED},
    RequestState.PREFILL: {RequestState.DECODE, RequestState.PREEMPTED,
                           RequestState.FAILED},
    RequestState.DECODE: {RequestState.DECODE, RequestState.GATED,
                          RequestState.PREEMPTED, RequestState.FAILED},
    RequestState.GATED: {RequestState.ESCALATED, RequestState.DONE},
    RequestState.ESCALATED: {RequestState.PREFILL, RequestState.SHED},
    RequestState.PREEMPTED: {RequestState.PREFILL, RequestState.SHED},
    RequestState.SHED: set(),
    RequestState.FAILED: set(),
    RequestState.DONE: set(),
}

#: states a request can never leave (conservation: every submitted
#: request ends in exactly one of these)
TERMINAL_STATES = frozenset({RequestState.DONE, RequestState.SHED,
                             RequestState.FAILED})


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # [P] int32
    gen_len: int
    arrival_time: float
    # absolute completion deadline in the engine's clock domain; None =
    # no deadline.  The scheduler's shedding pass rejects queued requests
    # past (or provably unable to meet) it into the SHED terminal state.
    deadline: Optional[float] = None
    state: RequestState = RequestState.QUEUED
    tier: int = 0                         # current cascade member index
    slot: Optional[int] = None            # KV slot in the current tier pool
    preemptions: int = 0                  # times evicted and replayed

    tokens: List[int] = field(default_factory=list)       # current tier
    token_conf: List[float] = field(default_factory=list)
    # speculative cascade decoding: the cheap-tier row retained at
    # escalation to draft ahead of this request's expensive-tier decode,
    # plus the drafts it staged for the next verify pass.  Cleared by
    # the engine on every terminal/replay path (never by admit(), which
    # runs while the draft row is live).
    draft_tier: Optional[int] = None
    draft_slot: Optional[int] = None
    draft_tokens: List[int] = field(default_factory=list)
    draft_confs: List[float] = field(default_factory=list)
    seq_conf_by_tier: List[float] = field(default_factory=list)
    # per-tier token-stream snapshots (taken at gate time): tier t's
    # stream vs tier t+1's is the escalation-outcome agreement proxy
    # feeding the streaming calibration telemetry
    tokens_by_tier: List[List[int]] = field(default_factory=list)
    admit_times: List[float] = field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # lifecycle span log [(state, t), ...] in the engine's clock domain
    # (timestamps are None for transitions the caller didn't stamp);
    # the tracer keeps its own wall-clock spans — this is the
    # metrics-facing record
    span_log: List[tuple] = field(default_factory=list)

    def _to(self, state: RequestState) -> None:
        if state not in _ALLOWED[self.state]:
            raise ValueError(
                f"request {self.rid}: illegal transition "
                f"{self.state.value} -> {state.value}")
        self.state = state

    # -- lifecycle ---------------------------------------------------------

    def admit(self, tier: int, slot: int, now: float) -> None:
        """QUEUED/ESCALATED/PREEMPTED -> PREFILL in `tier` occupying
        `slot`.  Re-admission after preemption resets the tier's partial
        work (tokens/confidences) exactly like escalation does — greedy
        decode is deterministic, so the replay regenerates the identical
        stream."""
        if not self.span_log:
            self.span_log.append((RequestState.QUEUED.value,
                                  self.arrival_time))
        self._to(RequestState.PREFILL)
        self.tier = tier
        self.slot = slot
        self.tokens = []
        self.token_conf = []
        self.admit_times.append(now)
        self.span_log.append((RequestState.PREFILL.value, now))

    def start_decode(self, now: Optional[float] = None) -> None:
        self._to(RequestState.DECODE)
        self.span_log.append((RequestState.DECODE.value, now))

    def emit(self, token: int, conf: float, now: float) -> None:
        """Record one generated token + its gate confidence."""
        if self.state is not RequestState.DECODE:
            raise ValueError(f"request {self.rid}: emit in {self.state.value}")
        self.tokens.append(int(token))
        self.token_conf.append(float(conf))
        if self.first_token_time is None:
            self.first_token_time = now

    @property
    def prompt_tokens(self) -> int:
        """Prompt length in tokens (mixed-length serving: per request)."""
        return int(self.prompt.shape[0])

    @property
    def decode_finished(self) -> bool:
        return len(self.tokens) >= self.gen_len

    def gate(self, reduce: str = "mean") -> float:
        """DECODE -> GATED; returns the aggregated sequence confidence."""
        self._to(RequestState.GATED)
        conf = sequence_confidence(self.token_conf, reduce)
        self.seq_conf_by_tier.append(conf)
        self.tokens_by_tier.append(list(self.tokens))
        return conf

    def escalate(self, now: Optional[float] = None) -> None:
        """GATED -> ESCALATED (will queue for tier+1)."""
        self._to(RequestState.ESCALATED)
        self.slot = None
        self.span_log.append((RequestState.ESCALATED.value, now))

    def preempt(self, now: Optional[float] = None) -> None:
        """PREFILL/DECODE -> PREEMPTED: evicted from its row, partial
        tier work discarded; the engine re-queues it for replay."""
        self._to(RequestState.PREEMPTED)
        self.slot = None
        self.preemptions += 1
        self.span_log.append((RequestState.PREEMPTED.value, now))

    def shed(self, now: Optional[float] = None) -> None:
        """QUEUED/ESCALATED/PREEMPTED -> SHED (terminal): load-shedding
        rejected this request (deadline passed or provably unmeetable)."""
        self._to(RequestState.SHED)
        self.finish_time = None
        self.span_log.append((RequestState.SHED.value, now))

    def fail(self, now: Optional[float] = None) -> None:
        """PREFILL/DECODE -> FAILED (terminal): launch retries exhausted
        with this request chosen as the sacrifice."""
        self._to(RequestState.FAILED)
        self.slot = None
        self.span_log.append((RequestState.FAILED.value, now))

    def complete(self, now: float) -> None:
        self._to(RequestState.DONE)
        self.slot = None
        self.finish_time = now
        self.span_log.append((RequestState.DONE.value, now))

    # -- derived metrics ---------------------------------------------------

    @property
    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    @property
    def ttft(self) -> Optional[float]:
        """Time to first (fast-tier) token."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def num_escalations(self) -> int:
        return self.tier


def sequence_confidence(token_conf, reduce: str = "mean") -> float:
    """Aggregate per-token confidences (numpy twin of
    repro.core.confidence.sequence_confidence)."""
    c = np.asarray(token_conf, np.float64)
    if c.size == 0:
        return 0.0
    if reduce == "mean":
        return float(c.mean())
    if reduce == "min":
        return float(c.min())
    if reduce == "prod":
        return float(np.exp(np.log(np.clip(c, 1e-9, 1.0)).sum()))
    raise ValueError(reduce)
