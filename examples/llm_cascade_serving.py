"""LLM cascade serving: two assigned architectures (reduced variants)
behind the paper's confidence gate, with the Pallas confidence_gate kernel
(interpret mode on CPU) doing the routing.

Part 1 uses the synchronous compatibility wrapper (`serve_cascade`, now
driven by the async engine under the hood); part 2 drives
:class:`repro.serving.CascadeEngine` directly with staggered arrivals and
an escalation *budget* instead of a fixed δ.

    PYTHONPATH=src python examples/llm_cascade_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import bigram_lm
from repro.launch.serve import serve_cascade
from repro.models import init_params
from repro.serving import CascadeEngine, TierSpec
from repro.serving.engine import VirtualClock


def sync_demo():
    print("== synchronous wrapper (fixed δ sweep) ==")
    print("fast=gemma3-1b(smoke)  expensive=phi4-mini-3.8b(smoke)")
    for delta in (0.2, 0.5, 0.8):
        _, conf, stats = serve_cascade(
            "gemma3-1b", "phi4-mini-3.8b", variant="smoke", batch=8,
            prompt_len=32, gen_len=12, delta=delta, use_gate_kernel=True,
            pack=True, verbose=False)
        print(f"δ={delta:.1f}: escalated {stats.n_exp}/{stats.n}, "
              f"FLOPs/req {stats.flops_cascade:.3e} "
              f"(fast-only {stats.flops_fast:.3e}, "
              f"always-exp {stats.flops_fast + stats.flops_exp:.3e})")
    print("higher δ -> more escalation -> higher cost (Eq 7); the gate "
          "confidence comes from the fused Pallas kernel")


def async_demo():
    print("\n== async engine (continuous batching, escalation budget) ==")
    fast_cfg = get_config("gemma3-1b", "smoke")
    exp_cfg = get_config("phi4-mini-3.8b", "smoke")
    engine = CascadeEngine(
        [TierSpec("gemma3-1b", fast_cfg,
                  init_params(fast_cfg, jax.random.PRNGKey(0), jnp.float32)),
         TierSpec("phi4-mini-3.8b", exp_cfg,
                  init_params(exp_cfg, jax.random.PRNGKey(1), jnp.float32))],
        slots=4, prompt_len=32, gen_len=12,
        escalation_budget=0.25,          # δ calibrated online from traffic
        use_gate_kernel=True, clock=VirtualClock())
    vocab = min(fast_cfg.vocab_size, exp_cfg.vocab_size)
    prompts = bigram_lm(num_seqs=16, seq_len=32, vocab=vocab, seed=0)
    for i, p in enumerate(prompts):       # 16 requests into 4 slots/tier
        engine.submit(p, arrival_time=float(i // 2))
    s = engine.run()
    print(f"{s['completed']} requests over {s['steps']} ticks; "
          f"latency p50/p95 = {s['latency_p50']:.0f}/{s['latency_p95']:.0f} "
          f"ticks; escalation rate {s['escalation_rates'][0]:.2f} "
          f"(budget 0.25)")
    print(f"Eq7 FLOPs/req: cascade {s['flops_per_request_cascade']:.3e} < "
          f"always-expensive {s['flops_per_request_always_expensive']:.3e}")
    mix = np.bincount([r.tier for r in engine.requests], minlength=2)
    print(f"handled by: fast={mix[0]} expensive={mix[1]} "
          "(per-request routing, packed escalation sub-batches)")


def main():
    sync_demo()
    async_demo()


if __name__ == "__main__":
    main()
