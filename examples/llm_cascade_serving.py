"""LLM cascade serving: two assigned architectures (reduced variants)
behind the paper's confidence gate, with the Pallas confidence_gate kernel
(interpret mode on CPU) doing the routing.

    PYTHONPATH=src python examples/llm_cascade_serving.py
"""
from repro.launch.serve import serve_cascade


def main():
    print("fast=gemma3-1b(smoke)  expensive=phi4-mini-3.8b(smoke)")
    for delta in (0.2, 0.5, 0.8):
        _, conf, stats = serve_cascade(
            "gemma3-1b", "phi4-mini-3.8b", variant="smoke", batch=8,
            prompt_len=32, gen_len=12, delta=delta, use_gate_kernel=True,
            pack=True, verbose=False)
        print(f"δ={delta:.1f}: escalated {stats.n_exp}/{stats.n}, "
              f"FLOPs/req {stats.flops_cascade:.3e} "
              f"(fast-only {stats.flops_fast:.3e}, "
              f"always-exp {stats.flops_fast + stats.flops_exp:.3e})")
    print("higher δ -> more escalation -> higher cost (Eq 7); the gate "
          "confidence comes from the fused Pallas kernel")


if __name__ == "__main__":
    main()
