"""Quickstart: the paper's loss + cascade metrics in ~60 lines.

Trains a fast and an expensive classifier on the synthetic task, retrains
the fast one with Learning to Cascade (Eq 4), and compares the
accuracy/MACs trade-off (Eqs 2 and 7) of both cascades.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cascade, losses, thresholds
from repro.core import confidence as conf_lib
from repro.data.synthetic import teacher_task
from repro.models import classifier as clf


def main():
    print("1) data (synthetic stand-in for CIFAR-100, see DESIGN.md §6)")
    ds = teacher_task(num_samples=60000, seed=0)
    tr, va, te = ds.split((0.8, 0.1, 0.1))
    nc = int(tr.y.max()) + 1
    zoo = clf.zoo(in_dim=tr.x.shape[1], num_classes=nc)
    fast_cfg, exp_cfg = zoo["mobilenetv2"], zoo["resnet18"]

    print("2) train the expensive model (CE only)")
    key = jax.random.PRNGKey(0)
    exp_p = clf.train_classifier(exp_cfg, jnp.asarray(tr.x),
                                 jnp.asarray(tr.y), key=key, epochs=6,
                                 lr=0.03, batch_size=512)
    exp_train_logits, _ = clf.predict(exp_p, jnp.asarray(tr.x))

    print("3) train the fast model twice: CE (Baseline) and LtC (Eq 4)")
    fast_base = clf.train_classifier(fast_cfg, jnp.asarray(tr.x),
                                     jnp.asarray(tr.y), key=key, epochs=6,
                                     lr=0.03, batch_size=512)
    fast_ltc = clf.train_classifier(fast_cfg, jnp.asarray(tr.x),
                                    jnp.asarray(tr.y), key=key, epochs=6,
                                    lr=0.03, batch_size=512,
                                    exp_logits=exp_train_logits, ltc_w=1.0,
                                    cost_c=0.5)

    print("4) sweep δ on val, report test Acc^casc / MACs^casc (Eqs 2, 7)")
    costs = [fast_cfg.macs, exp_cfg.macs]
    for name, fp in (("baseline", fast_base), ("ltc", fast_ltc)):
        def stats(split):
            fl, _ = clf.predict(fp, jnp.asarray(split.x))
            y = jnp.asarray(split.y)
            return (np.asarray(conf_lib.max_prob(fl)),
                    np.asarray(losses.correct(fl, y)),
                    np.asarray(losses.correct(
                        clf.predict(exp_p, jnp.asarray(split.x))[0], y)))

        cv, fv, ev = stats(va)
        delta, _, _ = thresholds.best_accuracy_delta(cv, fv, ev, costs)
        ct, ft, et = stats(te)
        acc, macs, n_exp = cascade.two_element_metrics(
            jnp.asarray(ct), jnp.asarray(ft), jnp.asarray(et),
            costs[0], costs[1], delta)
        print(f"   {name:8s}: δ={delta:.2f}  Acc^casc={float(acc)*100:.2f}%"
              f"  MACs^casc={float(macs):.0f}"
              f"  (exp alone: {et.mean()*100:.2f}% @ {costs[1]})")


if __name__ == "__main__":
    main()
