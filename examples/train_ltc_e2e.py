"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps with the LtC objective against a frozen expensive model,
then serve the pair as a cascade and report the Eq-7 cost.

By default runs a reduced pair sized for CPU; pass --full-100m to train
the ~100M-parameter gemma3-family variant (same code path, longer run).

    PYTHONPATH=src python examples/train_ltc_e2e.py --steps 200
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import Attn, Dense, Layer
from repro.launch.serve import serve_cascade
from repro.launch.train import run as train_run


def hundred_m_config():
    """~100M-param dense decoder (gemma3 family, reduced)."""
    base = get_config("gemma3-1b")
    return dataclasses.replace(
        base, name="gemma3-100m",
        d_model=512, num_heads=8, num_kv_heads=2, head_dim=64,
        vocab_size=32768,
        period=(Layer(Attn(window=256), Dense(d_ff=2048)),) * 2,
        num_periods=6, tail=(),
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    if args.full_100m:
        from repro.configs.base import register
        from repro.models.params import param_count_from_decl
        cfg = register(hundred_m_config())
        print(f"training {cfg.name}: {param_count_from_decl(cfg)/1e6:.0f}M "
              f"params for {args.steps} steps")
        fast_arch, variant = cfg.name, None
    else:
        fast_arch, variant = "gemma3-1b", "smoke"

    print(f"== 1) pretrain the expensive member (phi4 family, {args.steps} steps)")
    exp_params = train_run("phi4-mini-3.8b", variant="smoke",
                           steps=args.steps, batch=args.batch, seq=args.seq,
                           lr=5e-3, log_every=max(args.steps // 4, 1))

    print("== 2) LtC-train the fast member against the frozen expensive one")
    fast_params, losses = train_run(
        fast_arch, variant=variant, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=5e-3, expensive="phi4-mini-3.8b", ltc_w=1.0,
        cost_c=0.5, exp_params=exp_params,
        log_every=max(args.steps // 4, 1), return_losses=True)
    print(f"   LtC loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("== 3) serve the cascade (δ=0.5), Eq-7 accounting")
    # NOTE: serve_cascade resolves the expensive member at the same
    # variant; the fast member's params come from step 2.
    _, _, stats = serve_cascade(
        fast_arch, "phi4-mini-3.8b", fast_variant=variant,
        exp_variant="smoke", batch=8, prompt_len=32, gen_len=12, delta=0.5,
        fast_params=fast_params, exp_params=exp_params, verbose=True)
    print(f"   cascade FLOPs/request: {stats.flops_cascade:.3e}")


if __name__ == "__main__":
    main()
