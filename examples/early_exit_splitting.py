"""Model splitting for an LLM (the paper's second setting, Eq 6):
a reduced gemma3-family decoder with early-exit heads after each scan
period, trained jointly with the LtC chain loss, then evaluated as a
multi-element cascade over exits.

    PYTHONPATH=src python examples/early_exit_splitting.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import cascade, losses
from repro.core import confidence as conf_lib
from repro.data import Batches, bigram_lm
from repro.launch import steps as steps_lib
from repro.models import forward, init_params
from repro.optim import get_optimizer


def main(steps=80, batch=8, seq=64):
    base = get_config("gemma3-1b", "smoke")
    cfg = dataclasses.replace(base, num_periods=3, early_exit_periods=(0, 1))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, jnp.float32)

    tokens = bigram_lm(num_seqs=256, seq_len=seq, vocab=cfg.vocab_size)
    it = iter(Batches({"tokens": tokens}, batch))
    opt = get_optimizer("adamw")
    state = opt.init(params)

    def loss_fn(p, b):
        logits, _, aux = forward(p, cfg, b, mode="train")
        labels = b["tokens"][:, 1:]
        chain = [el[:, :-1] for el in aux["exit_logits"]] + [logits[:, :-1]]
        return losses.ltc_chain_loss(chain, labels, w=1.0, cost_c=0.5)[0]

    @jax.jit
    def step(p, s, b):
        l, g = jax.value_and_grad(loss_fn)(p, b)
        p, s = opt.update(p, g, s, 3e-3)
        return p, s, l

    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, state, l = step(params, state, b)
        if (i + 1) % 20 == 0:
            print(f"step {i+1}: Eq-6 loss {float(l):.4f}")

    # evaluate the exits as a 3-element cascade on held-out data
    ev = {"tokens": jnp.asarray(bigram_lm(num_seqs=32, seq_len=seq,
                                          vocab=cfg.vocab_size, seed=9))}
    logits, _, aux = forward(params, cfg, ev, mode="train")
    labels = np.asarray(ev["tokens"][:, 1:]).reshape(-1)
    chain = [np.asarray(el[:, :-1]).reshape(len(labels), -1)
             for el in aux["exit_logits"]]
    chain.append(np.asarray(logits[:, :-1]).reshape(len(labels), -1))
    confs = np.stack([np.asarray(conf_lib.max_prob(jnp.asarray(c)))
                      for c in chain[:-1]])
    corr = np.stack([(c.argmax(-1) == labels).astype(np.float32)
                     for c in chain])
    # per-exit cost = cumulative periods (1, 2, 3 of 3)
    costs = np.array([1.0, 1.0, 1.0], np.float32)
    for delta in (0.3, 0.6, 0.9):
        out = cascade.evaluate_cascade(confs, corr, costs,
                                       np.array([[delta, delta]]))
        print(f"δ={delta:.1f}: token acc {float(out['acc'][0])*100:.2f}%  "
              f"mean depth {float(out['cost'][0]):.2f}/3 periods  "
              f"exit fractions {np.round(np.asarray(out['frac_used'][0]), 2)}")


if __name__ == "__main__":
    main()
