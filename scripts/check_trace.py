"""Chrome-trace-event schema validator for serving timelines.

``serve_async --trace-out trace.json`` emits a Chrome trace (the JSON
object format Perfetto loads); a malformed trace fails *silently* — the
viewer just renders nothing, or drops the broken track.  This script
checks the invariants the tracer promises, so CI catches a regression
before a human stares at an empty timeline:

  * top level: object with a ``traceEvents`` list
  * every event has ``name``/``ph``/``pid``/``tid`` and (except metadata
    ``M`` events) a numeric ``ts``
  * ``X`` complete events carry ``dur >= 0`` and nest properly per
    (pid, tid) track: a span never half-overlaps an enclosing span
  * ``b``/``e`` async events pair up per (cat, id): every ``e`` closes
    an open ``b``, no ``b`` left dangling, and each pair's track is
    consistent
  * per (pid, tid) track, ``X`` event start times are monotonic
    (non-decreasing) — the ring buffer must preserve emission order
  * ``C`` counter events carry numeric sample values in ``args``
  * ``M`` metadata events are ``process_name``/``thread_name``/
    ``process_sort_index`` with the matching ``args`` payload

Run it directly::

    python scripts/check_trace.py trace.json [more.json ...]

Exit status 0 when every file validates, 1 otherwise (one line per
violation, capped per file).  Wired into CI as a smoke on a sharded
serve_async run (.github/workflows/ci.yml).
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Tuple

# metadata events Perfetto understands (the tracer only emits the
# first two; the rest are legal Chrome trace vocabulary)
META_NAMES = {"process_name", "thread_name", "process_sort_index",
              "thread_sort_index", "process_labels"}
MAX_ERRORS_PER_FILE = 20


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_events(events: list) -> List[str]:
    """All schema violations in a traceEvents list (empty = valid)."""
    errors: List[str] = []
    # open X spans per (pid, tid), as a stack of (start, end) intervals
    x_stacks: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    last_x_ts: Dict[Tuple[int, int], float] = {}
    open_async: Dict[Tuple[str, str], List[dict]] = {}

    for i, ev in enumerate(events):
        where = f"event {i}"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        name = ev.get("name")
        where = f"event {i} ({ph!r} {name!r})"
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                errors.append(f"{where}: missing {key!r}")
        if ph == "M":
            if name not in META_NAMES:
                errors.append(f"{where}: unknown metadata event")
            elif name.endswith("_name") \
                    and not isinstance(ev.get("args", {}).get("name"), str):
                errors.append(f"{where}: metadata args.name missing")
            continue
        ts = ev.get("ts")
        if not _is_num(ts):
            errors.append(f"{where}: non-numeric ts {ts!r}")
            continue
        track = (ev.get("pid"), ev.get("tid"))

        if ph == "X":
            dur = ev.get("dur")
            if not _is_num(dur) or dur < 0:
                errors.append(f"{where}: X event needs dur >= 0, "
                              f"got {dur!r}")
                continue
            if ts < last_x_ts.get(track, float("-inf")):
                errors.append(f"{where}: ts {ts} before previous X start "
                              f"{last_x_ts[track]} on track {track}")
            last_x_ts[track] = ts
            # nesting: pop finished spans, then check containment
            stack = x_stacks.setdefault(track, [])
            while stack and stack[-1][1] <= ts:
                stack.pop()
            if stack and ts + dur > stack[-1][1]:
                errors.append(
                    f"{where}: span [{ts}, {ts + dur}] half-overlaps "
                    f"enclosing span ending {stack[-1][1]} on {track}")
            stack.append((ts, ts + dur))
        elif ph in ("b", "e"):
            if "id" not in ev:
                errors.append(f"{where}: async event missing id")
                continue
            key = (str(ev.get("cat", "")), str(ev["id"]))
            if ph == "b":
                open_async.setdefault(key, []).append(ev)
            else:
                stack = open_async.get(key)
                if not stack:
                    errors.append(f"{where}: 'e' with no open 'b' "
                                  f"for (cat, id)={key}")
                else:
                    stack.pop()
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args \
                    or not all(_is_num(v) for v in args.values()):
                errors.append(f"{where}: counter needs numeric args")
        elif ph == "i":
            pass  # instant: name/ph/ts/pid/tid already checked
        else:
            errors.append(f"{where}: unsupported phase {ph!r}")

    for key, stack in open_async.items():
        if stack:
            errors.append(f"(cat, id)={key}: {len(stack)} async 'b' "
                          f"event(s) never closed by 'e'")
    return errors


def validate_trace(trace: dict) -> List[str]:
    """Violations in a full trace object (``traceEvents`` + metadata)."""
    if not isinstance(trace, dict):
        return ["top level: trace must be a JSON object"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["top level: missing traceEvents list"]
    errors = validate_events(events)
    if not events:
        errors.append("top level: traceEvents is empty")
    return errors


def check_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"]
    return validate_trace(trace)


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: python scripts/check_trace.py trace.json [...]")
        return 1
    bad = 0
    for path in argv:
        errors = check_file(path)
        if errors:
            bad += 1
            for e in errors[:MAX_ERRORS_PER_FILE]:
                print(f"{path}: {e}")
            if len(errors) > MAX_ERRORS_PER_FILE:
                print(f"{path}: ... {len(errors) - MAX_ERRORS_PER_FILE} "
                      f"more violations")
        else:
            n = 0
            with open(path) as f:
                n = len(json.load(f)["traceEvents"])
            print(f"{path}: ok ({n} events)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
