"""Quick dev driver: run every smoke-variant arch through fwd/prefill/decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill)

names = sys.argv[1:] or ASSIGNED
for name in names:
    cfg = get_config(name, "smoke")
    key = jax.random.PRNGKey(0)
    p = init_params(cfg, key, jnp.float32)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    logits, _, aux = forward(p, cfg, batch, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size), logits.shape
    assert not jnp.isnan(logits).any(), "train NaN"

    # prefill then decode one token
    logits_p, cache, _ = forward(p, cfg, batch, mode="prefill")
    # decode path needs a full-size cache: rebuild at S+4 and re-prefill layout
    cache_full = init_cache(cfg, B, S + 4, jnp.float32)

    def put(full, part):
        if full.shape == part.shape:
            return part.astype(full.dtype)
        # attn kv caches: write the prefix
        idx = tuple(slice(0, s) for s in part.shape)
        return full.at[idx].set(part.astype(full.dtype))

    cache_full = jax.tree.map(put, cache_full, cache)
    tok = batch["tokens"][:, -1:]
    pos = jnp.full((B, 1), S, jnp.int32)
    logits_d, cache2 = decode_step(p, cfg, tok, cache_full, pos)
    assert logits_d.shape == (B, 1, cfg.vocab_size)
    assert not jnp.isnan(logits_d).any(), "decode NaN"
    print(f"OK {name}: train+prefill+decode, logits mean {float(logits.mean()):+.4f}")
