import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: lower a (arch × shape) pair under named
variants and print the roofline terms side by side.

    PYTHONPATH=src python scripts/hillclimb.py kimi_train
    PYTHONPATH=src python scripts/hillclimb.py gemma_decode
    PYTHONPATH=src python scripts/hillclimb.py moe_group
"""
import dataclasses as dc
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import steps as steps_lib
from repro.launch.dryrun import _terms, corrected_costs, lower_cfg
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.launch.shapes import SHAPES, input_specs
from repro.models import params as params_lib
from repro.models import sharding as sharding_lib


def measure(cfg, shape_name, mesh, *, correct=True, microbatches=1,
            seq_over_model=False, chunked_ce=0, label=""):
    if chunked_ce:
        pshapes = params_lib.param_shapes(cfg, dtype=jnp.bfloat16, mesh=mesh)
        inputs = input_specs(cfg, shape_name, mesh, dtype=jnp.bfloat16)
        with sharding_lib.set_mesh(mesh):
            step, opt = steps_lib.make_train_step(cfg, chunked_ce=chunked_ce)
            osh = steps_lib.opt_state_shapes(opt, cfg, mesh)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                pshapes, osh, inputs)
        compiled = lowered.compile()
    elif microbatches > 1:
        # custom lowering with grad accumulation
        pshapes = params_lib.param_shapes(cfg, dtype=jnp.bfloat16, mesh=mesh)
        inputs = input_specs(cfg, shape_name, mesh, dtype=jnp.bfloat16)
        with sharding_lib.set_mesh(mesh):
            step, opt = steps_lib.make_train_step(cfg,
                                                  microbatches=microbatches)
            osh = steps_lib.opt_state_shapes(opt, cfg, mesh)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                pshapes, osh, inputs)
        compiled = lowered.compile()
    elif seq_over_model:
        pshapes = params_lib.param_shapes(cfg, dtype=jnp.bfloat16, mesh=mesh)
        inputs = input_specs(cfg, shape_name, mesh, dtype=jnp.bfloat16,
                             seq_over_model=True)
        with sharding_lib.set_mesh(mesh):
            serve_step = steps_lib.make_serve_step(cfg)
            lowered = jax.jit(serve_step, donate_argnums=(3,)).lower(
                pshapes, inputs["token"], inputs["pos"], inputs["cache"])
        compiled = lowered.compile()
    else:
        compiled = lower_cfg(cfg, shape_name, mesh).compile()
    mem = compiled.memory_analysis()
    if correct and cfg.num_periods > 2 and microbatches == 1 \
            and not chunked_ce:
        terms = corrected_costs(cfg, shape_name, mesh)
    else:
        terms = _terms(compiled)
    t_c = terms["flops"] / PEAK_FLOPS_BF16
    t_m = terms["bytes"] / HBM_BW
    t_x = terms["wire"] / ICI_BW
    print(f"  [{label}] compute={t_c*1e3:9.2f}ms memory={t_m*1e3:9.2f}ms "
          f"collective={t_x*1e3:9.2f}ms temp={mem.temp_size_in_bytes/1e9:7.1f}GB "
          f"args={mem.argument_size_in_bytes/1e9:6.1f}GB")
    return {"t_c": t_c, "t_m": t_m, "t_x": t_x,
            "temp_gb": mem.temp_size_in_bytes / 1e9, "terms": terms}


def kimi_train():
    """Pair 1 (worst memory / collective): kimi-k2 x train_4k.
    Lever A: gradient accumulation (microbatches)."""
    mesh = make_production_mesh()
    cfg = get_config("kimi-k2-1t-a32b")
    print("kimi-k2-1t-a32b x train_4k @16x16")
    measure(cfg, "train_4k", mesh, label="baseline")
    for mb in (4, 8):
        measure(cfg, "train_4k", mesh, microbatches=mb, label=f"mb={mb}")


def moe_group():
    """Pair 1 lever B: MoE dispatch group size (dispatch einsum FLOPs are
    linear in group size: 2·tokens·gs·k·cf·D)."""
    import repro.models.blocks as blocks
    mesh = make_production_mesh()
    cfg = get_config("kimi-k2-1t-a32b")
    print("kimi-k2 x train_4k: MOE_GROUP_SIZE sweep")
    for gs in (1024, 512, 256):
        blocks.MOE_GROUP_SIZE = gs
        measure(cfg, "train_4k", mesh, label=f"gs={gs}")
    blocks.MOE_GROUP_SIZE = 1024


def gemma_decode():
    """Pair 3 (paper-representative: the cascade's fast member serving):
    gemma3-1b x decode_32k.  Lever: int8 KV cache."""
    mesh = make_production_mesh()
    cfg = get_config("gemma3-1b")
    print("gemma3-1b x decode_32k @16x16")
    measure(cfg, "decode_32k", mesh, label="baseline bf16 cache")
    measure(dc.replace(cfg, kv_quant="int8"), "decode_32k", mesh,
            label="int8 KV cache")


def qwen_decode():
    """Pair 2: qwen2-vl-72b x decode_32k (biggest dense decode; its kv=8
    heads can't shard the 16-way model axis, so the cache replicates).
    Levers: shard cache seq over model; int8 KV cache; both."""
    mesh = make_production_mesh()
    cfg = get_config("qwen2-vl-72b")
    print("qwen2-vl-72b x decode_32k @16x16")
    measure(cfg, "decode_32k", mesh, label="baseline bf16 cache")
    measure(cfg, "decode_32k", mesh, seq_over_model=True,
            label="cache seq/model")
    measure(dc.replace(cfg, kv_quant="int8"), "decode_32k", mesh,
            label="int8 KV cache")
    measure(dc.replace(cfg, kv_quant="int8"), "decode_32k", mesh,
            seq_over_model=True, label="int8 + seq/model")


def chunked_ce():
    """Iteration 8: seq-chunked CE on the vocab-heavy archs — the logits
    [B,S,V] f32 transient should stop dominating temp memory.
    (cost terms not scan-corrected here; compare temp only)"""
    mesh = make_production_mesh()
    for arch in ("gemma3-1b", "phi4-mini-3.8b"):
        cfg = get_config(arch)
        print(f"{arch} x train_4k @16x16 (temp comparison)")
        measure(cfg, "train_4k", mesh, correct=False, label="baseline")
        measure(cfg, "train_4k", mesh, chunked_ce=512, label="chunked_ce=512")


def starcoder_train():
    """Pair 2 (most collective-bound: 6.5 TB/chip of all-gathers).
    Hypothesis: the T-sharded probs are all-gathered (9.7 GB x725)
    because v is not T-sharded; kv_seq_hint should turn the contraction
    into partial sums + a small out all-reduce."""
    mesh = make_production_mesh()
    cfg = get_config("starcoder2-7b")
    print("starcoder2-7b x train_4k @16x16")
    measure(cfg, "train_4k", mesh, label="baseline")
    measure(dc.replace(cfg, kv_seq_hint=True), "train_4k", mesh,
            label="kv_seq_hint")


def moonshot_train():
    """Pair 2 (collective-bound candidate): moonshot x train_4k.
    Lever: fsdp (2D weight sharding) on/off."""
    mesh = make_production_mesh()
    cfg = get_config("moonshot-v1-16b-a3b")
    print("moonshot-v1-16b-a3b x train_4k @16x16")
    measure(cfg, "train_4k", mesh, label="baseline (no fsdp)")
    measure(dc.replace(cfg, fsdp=True), "train_4k", mesh, label="fsdp=True")


EXPERIMENTS = {
    "kimi_train": kimi_train,
    "moe_group": moe_group,
    "gemma_decode": gemma_decode,
    "qwen_decode": qwen_decode,
    "starcoder_train": starcoder_train,
    "chunked_ce": chunked_ce,
    "moonshot_train": moonshot_train,
}

if __name__ == "__main__":
    for name in sys.argv[1:] or list(EXPERIMENTS):
        EXPERIMENTS[name]()
