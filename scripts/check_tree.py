"""Repo-hygiene guard: fail when git tracks build artifacts.

Commit ca4bfbe shipped three ``__pycache__/*.pyc`` files because the repo
had no ``.gitignore``; this script makes that class of regression a CI
failure instead of a review catch.  It lists the files git tracks and
rejects anything that is a Python bytecode cache, a pytest cache, or an
egg-info directory — artifacts that are machine-local and never belong
in history.

Run it directly::

    python scripts/check_tree.py

Exit status 0 when the tree is clean, 1 otherwise (one line per tracked
artifact).  Wired into CI (.github/workflows/ci.yml) next to
``scripts/check_docs.py``.
"""
from __future__ import annotations

import re
import subprocess
import sys
from typing import List

# path patterns that must never be tracked by git
ARTIFACTS = re.compile(
    r"(^|/)__pycache__(/|$)"
    r"|\.py[co]$"
    r"|(^|/)\.pytest_cache(/|$)"
    r"|\.egg-info(/|$)"
    r"|(^|/)\.hypothesis(/|$)")


def tracked_artifacts(files: List[str]) -> List[str]:
    """The subset of `files` that are build/cache artifacts."""
    return [f for f in files if ARTIFACTS.search(f)]


def main() -> int:
    files = subprocess.run(
        ["git", "ls-files"], capture_output=True, text=True,
        check=True).stdout.splitlines()
    bad = tracked_artifacts(files)
    for f in bad:
        print(f"check_tree: tracked build artifact: {f}", file=sys.stderr)
    print(f"check_tree: {len(files)} tracked file(s), "
          f"{len(bad)} artifact(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
