"""Doc-reference checker: every ``path/file.py`` (and
``path/file.py::symbol``) mentioned in README.md / docs/*.md must resolve
against the tree.

Docs rot silently — the PR 1 review already caught a stale docstring, and
a paper-to-code map is only useful while its file:symbol references are
real.  This script extracts path-shaped references from the markdown
documentation and fails CI when one no longer resolves:

  * ``some/path.py`` (also .md/.yml/.yaml/.txt/.json/.sh/.toml) — must
    exist relative to the repo root, ``src/``, or ``src/repro/`` (docs
    refer to modules the way imports do, e.g. ``serving/slots.py``).
  * ``some/path.py::symbol`` — the file must exist *and* every dotted
    component of ``symbol`` must occur as a word in it (functions,
    classes, methods, test names).

URLs and glob patterns are ignored.  Run it directly::

    python scripts/check_docs.py            # README.md + docs/*.md
    python scripts/check_docs.py FILE...    # explicit files

Exit status 0 when every reference resolves, 1 otherwise (one line per
broken reference).  Wired into CI (.github/workflows/ci.yml).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List, Optional, Tuple

EXTS = "py|md|yml|yaml|txt|json|sh|toml"

# `path/to/file.py::symbol` or bare `path/to/file.py` in backticks or
# prose; paths start with a word character and may contain / . - _
_REF = re.compile(
    rf"(?P<path>[A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:{EXTS}))"
    rf"(?:::(?P<symbol>[A-Za-z_][A-Za-z0-9_.]*))?")

# roots a doc path may be relative to (docs refer to python modules the
# way imports see them: `serving/slots.py` means src/repro/serving/...)
SEARCH_ROOTS = ("", "src", "src/repro")


def find_refs(text: str) -> List[Tuple[int, str, Optional[str]]]:
    """(lineno, path, symbol-or-None) for every reference in `text`."""
    refs = []
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in _REF.finditer(line):
            start = m.start()
            prefix = line[:start]
            # skip URLs (http://host/x.py) and glob patterns (docs/*.md)
            if prefix.rstrip().endswith(("://", "/")) and "://" in prefix:
                continue
            # skip glob/shell-var prefixes (*$) and absolute paths
            # (/tmp/trace.json — an output placeholder, not a repo ref)
            if start >= 1 and line[start - 1] in "*$/":
                continue
            refs.append((lineno, m.group("path"), m.group("symbol")))
    return refs


def resolve(path: str, root: Path) -> Optional[Path]:
    """First existing candidate for a doc path, or None."""
    for base in SEARCH_ROOTS:
        cand = root / base / path
        if cand.is_file():
            return cand
    return None


def check_text(text: str, root: Path, name: str = "<doc>") -> List[str]:
    """Error strings for every unresolvable reference in `text`."""
    errors = []
    bodies = {}                 # resolved path -> file text (docs cite the
    for lineno, path, symbol in find_refs(text):    # same modules often)
        target = resolve(path, root)
        if target is None:
            errors.append(f"{name}:{lineno}: `{path}` not found under "
                          f"{{{', '.join(r or '.' for r in SEARCH_ROOTS)}}}")
            continue
        if symbol is None:
            continue
        if target not in bodies:
            bodies[target] = target.read_text(encoding="utf-8",
                                              errors="replace")
        body = bodies[target]
        for part in symbol.split("."):
            if not re.search(rf"\b{re.escape(part)}\b", body):
                errors.append(
                    f"{name}:{lineno}: `{path}::{symbol}` — "
                    f"no symbol `{part}` in {target.relative_to(root)}")
                break
    return errors


def check_file(md_path: Path, root: Path) -> List[str]:
    try:
        name = str(md_path.relative_to(root))
    except ValueError:                  # e.g. a tmp file under test
        name = str(md_path)
    return check_text(md_path.read_text(encoding="utf-8"), root, name)


def default_docs(root: Path) -> List[Path]:
    docs = []
    readme = root / "README.md"
    if readme.is_file():
        docs.append(readme)
    docs.extend(sorted((root / "docs").glob("*.md")))
    return docs


def main(argv: List[str]) -> int:
    root = Path(__file__).resolve().parent.parent
    files = [Path(a).resolve() for a in argv] if argv else default_docs(root)
    if not files:
        print("check_docs: no documentation files found", file=sys.stderr)
        return 1
    errors = []
    checked = 0
    for f in files:
        errors.extend(check_file(f, root))
        checked += 1
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs: {checked} file(s), "
          f"{len(errors)} broken reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
